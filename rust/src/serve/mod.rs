//! Multi-tenant serving layer (DESIGN.md §11): a bounded request queue
//! feeding a pool of worker threads, an LRU [`SessionRegistry`] of warm
//! epoch-persistent sessions sharing one on-disk [`PlanCache`], admission
//! control with structured back-pressure, and a micro-batcher that
//! coalesces same-graph SpMM requests into one multi-RHS execute.
//!
//! Request path: `try_submit` → admission (unknown graph / saturated queue
//! / shut down are *eager, structured* rejections — a client is never left
//! hanging) → FIFO queue → a worker pops the head and coalesces up to
//! `max_batch − 1` queued requests for the same graph (thread-backend SpMM
//! only) → session lookup in the registry (miss ⇒ plan through the shared
//! cache + build a session, evicting LRU at capacity) → one `execute` →
//! per-request results fulfilled through [`Ticket`]s.
//!
//! Batching is column concatenation: distributed SpMM is column-independent
//! bitwise (each output column is a function of the same A blocks and that
//! B column alone, folded in the same canonical order), so executing the
//! concatenation and splitting the output columns back per request is
//! **bitwise identical** to executing each request alone. `serve --bench`
//! re-proves this on every run; `tests/serve_suite.rs` pins it.
//!
//! Servers built with `workers == 0` never spawn threads: tests drive the
//! queue deterministically with [`Server::drain_one`] / [`Server::drain_all`].

pub mod bench;
pub mod registry;

pub use registry::{SessionKey, SessionRegistry};

use crate::dense::Dense;
use crate::exec::kernel::KernelOp;
use crate::exec::session::SpmmSession;
use crate::exec::{ExecOpts, ExecStats};
use crate::metrics::{latency_stats, LatencyStats};
use crate::plan::cache::{csr_fingerprint, PlanCache};
use crate::runtime::multiproc::PoolHandle;
use crate::sparse::Csr;
use crate::spmm::{Backend, ExecError, ExecRequest, ExecResult, FaultPolicy, PlanSpec, RecoveryReport};
use crate::topology::Topology;
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::{self, JoinHandle};
use std::time::Instant;

/// Everything a [`Server`] needs to know up front. All requests plan with
/// the same [`PlanSpec`] and execute with the same [`ExecOpts`]; per-request
/// variation is the graph, the kernel op, and the backend.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads. `0` = no threads; drive with [`Server::drain_one`].
    pub workers: usize,
    /// Queue bound: submissions beyond this are rejected
    /// [`ServeError::Saturated`] (back-pressure, never unbounded growth).
    pub queue_cap: usize,
    /// Max live sessions in the LRU registry.
    pub registry_cap: usize,
    /// Micro-batch bound: a worker coalesces at most this many same-graph
    /// SpMM requests into one execute. `1` disables batching.
    pub max_batch: usize,
    /// How every tenant's graph is planned (strategy, topology, hierarchy,
    /// partitioner, planner params).
    pub spec: PlanSpec,
    /// Executor scheduling options shared by all sessions.
    pub opts: ExecOpts,
    /// Disk-backed plan cache directory (`None` = in-memory only).
    pub cache_dir: Option<PathBuf>,
    /// Crash handling for proc-backend requests: [`FaultPolicy::Fail`]
    /// surfaces worker deaths as [`ServeError::Exec`];
    /// [`FaultPolicy::Recover`] replans over the survivors so a tenant
    /// request outlives a dead worker (DESIGN.md §12).
    pub fault_policy: FaultPolicy,
}

impl ServeConfig {
    pub fn new(topo: Topology) -> ServeConfig {
        ServeConfig {
            workers: 2,
            queue_cap: 64,
            registry_cap: 4,
            max_batch: 8,
            spec: PlanSpec::new(topo),
            opts: ExecOpts::default(),
            cache_dir: None,
            fault_policy: FaultPolicy::Fail,
        }
    }
}

/// One tenant request: which registered graph, which kernel, owned
/// operands (the client thread hands them off), and where to run.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub graph: String,
    pub op: KernelOp,
    /// B operand (SpMM) or Y (SDDMM-family).
    pub b: Dense,
    /// X operand (SDDMM-family only).
    pub x: Option<Dense>,
    pub backend: Backend,
}

impl ServeRequest {
    pub fn spmm(graph: &str, b: Dense) -> ServeRequest {
        ServeRequest {
            graph: graph.to_string(),
            op: KernelOp::Spmm,
            b,
            x: None,
            backend: Backend::Thread,
        }
    }

    pub fn sddmm(graph: &str, x: Dense, y: Dense) -> ServeRequest {
        ServeRequest { op: KernelOp::Sddmm, x: Some(x), ..ServeRequest::spmm(graph, y) }
    }

    pub fn fused(graph: &str, x: Dense, y: Dense) -> ServeRequest {
        ServeRequest { op: KernelOp::FusedSddmmSpmm, x: Some(x), ..ServeRequest::spmm(graph, y) }
    }

    pub fn backend(mut self, backend: Backend) -> ServeRequest {
        self.backend = backend;
        self
    }
}

/// What a fulfilled request gets back: the result plus its end-to-end
/// latency breakdown (queue wait, session plan/build time — zero on a
/// registry hit — and execute wall time) and the batch it rode in.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub dense: Option<Dense>,
    pub sparse: Option<Csr>,
    pub stats: ExecStats,
    pub queue_secs: f64,
    pub plan_secs: f64,
    pub exec_secs: f64,
    /// Number of requests coalesced into the execute that produced this
    /// response (1 = unbatched).
    pub batch_size: usize,
    /// Crash-recovery report when this request's proc-backend execute lost
    /// and recovered workers; `None` on clean runs and thread requests.
    pub recovery: Option<RecoveryReport>,
}

impl ServeResponse {
    /// The dense output; panics on an SDDMM response.
    pub fn into_dense(self) -> Dense {
        self.dense.expect("request produced a sparse result, not dense")
    }

    /// The sparse output; panics on a dense-output response.
    pub fn into_sparse(self) -> Csr {
        self.sparse.expect("request produced a dense result, not sparse")
    }
}

/// Structured rejection / failure. Admission errors (`Saturated`,
/// `UnknownGraph`, `Shutdown`) return from `try_submit` without queueing;
/// `Exec` arrives through the ticket when execution itself failed.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Back-pressure: the queue is at `queue_cap`. Retry later.
    Saturated { cap: usize },
    /// The request names a graph never passed to `register_graph`.
    UnknownGraph(String),
    /// The server shut down before (or while) the request was queued.
    Shutdown,
    /// Execution failed (rank failure on the proc backend, malformed
    /// operands, ...).
    Exec(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Saturated { cap } => {
                write!(f, "request queue saturated (cap {cap}); retry later")
            }
            ServeError::UnknownGraph(g) => write!(f, "unknown graph {g:?}; register it first"),
            ServeError::Shutdown => write!(f, "server shut down before the request executed"),
            ServeError::Exec(m) => write!(f, "execution failed: {m}"),
        }
    }
}

impl std::error::Error for ServeError {}

type TicketSlot = Arc<(Mutex<Option<Result<ServeResponse, ServeError>>>, Condvar)>;

/// A claim on one submitted request's eventual outcome. Every admitted
/// request is fulfilled exactly once — with its response, an
/// [`ServeError::Exec`], or [`ServeError::Shutdown`] — so `wait` never
/// hangs on a live-or-stopping server.
pub struct Ticket {
    slot: TicketSlot,
}

impl Ticket {
    /// Block until the request is fulfilled.
    pub fn wait(self) -> Result<ServeResponse, ServeError> {
        let (lock, cond) = &*self.slot;
        let mut slot = lock.lock().unwrap();
        loop {
            match slot.take() {
                Some(res) => return res,
                None => slot = cond.wait(slot).unwrap(),
            }
        }
    }

    /// The outcome if already fulfilled, without blocking.
    pub fn try_wait(&self) -> Option<Result<ServeResponse, ServeError>> {
        self.slot.0.lock().unwrap().take()
    }
}

fn fulfill(slot: &TicketSlot, res: Result<ServeResponse, ServeError>) {
    let (lock, cond) = &**slot;
    *lock.lock().unwrap() = Some(res);
    cond.notify_all();
}

/// Counters and per-request latency samples, snapshot via
/// [`Server::stats`] (registry counters are merged in at snapshot time).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub completed: u64,
    /// Admission rejections (saturated / unknown graph / shut down) plus
    /// requests drained with `Shutdown` errors.
    pub rejected: u64,
    /// Requests fulfilled with [`ServeError::Exec`].
    pub failed: u64,
    /// Coalesced execute calls (size ≥ 2).
    pub batches: u64,
    /// Requests that rode in those coalesced executes.
    pub batched_requests: u64,
    pub max_batch_seen: usize,
    pub registry_hits: u64,
    pub registry_misses: u64,
    pub registry_evictions: u64,
    /// Per-request samples, one entry per completed request.
    pub queue_secs: Vec<f64>,
    pub plan_secs: Vec<f64>,
    pub exec_secs: Vec<f64>,
    /// Submit-to-fulfill wall time.
    pub total_secs: Vec<f64>,
    /// Replan rounds performed by proc-backend crash recovery.
    pub recoveries: u64,
    /// One sample per replan round: failure detected → jobs re-shipped.
    pub recovery_secs: Vec<f64>,
    /// Worker processes spawned by the server's proc-backend pools
    /// (cold starts plus re-admissions), summed over every pool.
    pub pool_spawns: u64,
    /// Proc requests served over already-live pool connections — nonzero
    /// means the respawn-per-request overhead is actually amortized.
    pub pool_reuses: u64,
    /// Workers respawned and re-admitted after a mid-request loss.
    pub pool_readmissions: u64,
}

impl ServeStats {
    /// Order statistics over end-to-end request latency.
    pub fn latency(&self) -> LatencyStats {
        latency_stats(&self.total_secs)
    }

    /// Order statistics plus total over the replan latency samples
    /// ([`crate::metrics::recovery_latency`]).
    pub fn recovery_latency(&self) -> (LatencyStats, f64) {
        crate::metrics::recovery_latency(&self.recovery_secs)
    }

    /// Mean size of coalesced executes counting singletons, i.e. requests
    /// per execute call (1.0 = batching never engaged).
    pub fn mean_batch(&self) -> f64 {
        let singles = self.completed.saturating_sub(self.batched_requests);
        let execs = singles + self.batches;
        if execs == 0 {
            0.0
        } else {
            self.completed as f64 / execs as f64
        }
    }

    /// Registry hit rate over all session lookups (0.0 when none).
    pub fn hit_rate(&self) -> f64 {
        let total = self.registry_hits + self.registry_misses;
        if total == 0 {
            0.0
        } else {
            self.registry_hits as f64 / total as f64
        }
    }
}

struct Graph {
    a: Csr,
    fp: u64,
}

struct Pending {
    req: ServeRequest,
    slot: TicketSlot,
    enqueued: Instant,
}

struct Queue {
    deque: VecDeque<Pending>,
    open: bool,
}

struct Inner {
    cfg: ServeConfig,
    graphs: RwLock<HashMap<String, Arc<Graph>>>,
    queue: Mutex<Queue>,
    ready: Condvar,
    registry: Mutex<SessionRegistry>,
    cache: Mutex<PlanCache>,
    stats: Mutex<ServeStats>,
    /// One persistent proc worker pool per (topology, nranks): every
    /// proc-backend tenant on the same fleet shape shares warm workers
    /// instead of respawning rank processes per request. Fleets live
    /// until the server itself drops.
    pools: Mutex<HashMap<(String, usize), PoolHandle>>,
}

/// The multi-tenant server. Shared-reference methods (`register_graph`,
/// `try_submit`, `stats`, `drain_*`) are safe from any thread; `shutdown`
/// stops admission, joins the workers, and drains stragglers with
/// structured errors (also run on drop).
pub struct Server {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    pub fn new(cfg: ServeConfig) -> Server {
        assert!(cfg.queue_cap >= 1, "queue capacity must be >= 1");
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        let cache = match &cfg.cache_dir {
            Some(dir) => PlanCache::with_dir(dir),
            None => PlanCache::in_memory(),
        };
        let inner = Arc::new(Inner {
            graphs: RwLock::new(HashMap::new()),
            queue: Mutex::new(Queue { deque: VecDeque::new(), open: true }),
            ready: Condvar::new(),
            registry: Mutex::new(SessionRegistry::new(cfg.registry_cap)),
            cache: Mutex::new(cache),
            stats: Mutex::new(ServeStats::default()),
            pools: Mutex::new(HashMap::new()),
            cfg,
        });
        let workers = (0..inner.cfg.workers)
            .map(|i| {
                let inner = inner.clone();
                thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || while step(&inner, true) {})
                    .expect("spawn serve worker")
            })
            .collect();
        Server { inner, workers }
    }

    /// Register (or replace) a tenant graph under `name`. Fingerprinted
    /// once here; requests refer to graphs by name only.
    pub fn register_graph(&self, name: &str, a: Csr) {
        let fp = csr_fingerprint(&a);
        self.inner.graphs.write().unwrap().insert(name.to_string(), Arc::new(Graph { a, fp }));
    }

    /// Admission control: queue the request or reject it *now* with a
    /// structured error. Never blocks on a full queue.
    pub fn try_submit(&self, req: ServeRequest) -> Result<Ticket, ServeError> {
        if !self.inner.graphs.read().unwrap().contains_key(&req.graph) {
            self.inner.stats.lock().unwrap().rejected += 1;
            return Err(ServeError::UnknownGraph(req.graph));
        }
        let mut q = self.inner.queue.lock().unwrap();
        if !q.open {
            drop(q);
            self.inner.stats.lock().unwrap().rejected += 1;
            return Err(ServeError::Shutdown);
        }
        if q.deque.len() >= self.inner.cfg.queue_cap {
            let cap = self.inner.cfg.queue_cap;
            drop(q);
            self.inner.stats.lock().unwrap().rejected += 1;
            return Err(ServeError::Saturated { cap });
        }
        let slot: TicketSlot = Arc::new((Mutex::new(None), Condvar::new()));
        q.deque.push_back(Pending { req, slot: slot.clone(), enqueued: Instant::now() });
        drop(q);
        self.inner.ready.notify_one();
        Ok(Ticket { slot })
    }

    /// Submit and block for the outcome (the closed-loop clients' path).
    pub fn submit_wait(&self, req: ServeRequest) -> Result<ServeResponse, ServeError> {
        self.try_submit(req)?.wait()
    }

    /// Process the next queued request inline (plus whatever coalesces
    /// with it); `false` when the queue is empty. The deterministic drive
    /// for `workers == 0` servers.
    pub fn drain_one(&self) -> bool {
        step(&self.inner, false)
    }

    /// [`Server::drain_one`] until empty; returns the number of execute
    /// calls performed (batches count once).
    pub fn drain_all(&self) -> usize {
        let mut n = 0;
        while self.drain_one() {
            n += 1;
        }
        n
    }

    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().deque.len()
    }

    /// Snapshot of the counters and latency samples so far, with the
    /// registry's hit/miss/eviction counters merged in.
    pub fn stats(&self) -> ServeStats {
        let mut s = self.inner.stats.lock().unwrap().clone();
        {
            let reg = self.inner.registry.lock().unwrap();
            s.registry_hits = reg.hits;
            s.registry_misses = reg.misses;
            s.registry_evictions = reg.evictions;
        }
        for h in self.inner.pools.lock().unwrap().values() {
            let p = h.stats();
            s.pool_spawns += p.spawns;
            s.pool_reuses += p.reuses;
            s.pool_readmissions += p.readmissions;
        }
        s
    }

    /// Stop admission, join the workers (they finish in-flight batches),
    /// fulfill anything still queued with [`ServeError::Shutdown`], and
    /// return the final stats. Idempotent.
    pub fn shutdown(&mut self) -> ServeStats {
        self.inner.queue.lock().unwrap().open = false;
        self.inner.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let leftovers: Vec<Pending> = {
            let mut q = self.inner.queue.lock().unwrap();
            q.deque.drain(..).collect()
        };
        if !leftovers.is_empty() {
            self.inner.stats.lock().unwrap().rejected += leftovers.len() as u64;
            for p in &leftovers {
                fulfill(&p.slot, Err(ServeError::Shutdown));
            }
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Pop one request (blocking on the condvar if `block`), coalesce, and
/// execute. Returns `false` when there is nothing to do (queue empty and
/// either non-blocking or closed).
fn step(inner: &Inner, block: bool) -> bool {
    let mut q = inner.queue.lock().unwrap();
    let batch = loop {
        if let Some(head) = q.deque.pop_front() {
            break collect_batch(inner, &mut q, head);
        }
        if !q.open || !block {
            return false;
        }
        q = inner.ready.wait(q).unwrap();
    };
    drop(q);
    process(inner, batch);
    true
}

/// Micro-batcher: starting from `head`, pull queued requests that can ride
/// the same execute — same graph, thread-backend SpMM, same B row count —
/// up to `max_batch`. Non-matching requests keep their queue positions.
fn collect_batch(inner: &Inner, q: &mut Queue, head: Pending) -> Vec<Pending> {
    let coalescable = head.req.op == KernelOp::Spmm && matches!(head.req.backend, Backend::Thread);
    let mut batch = vec![head];
    if !coalescable || inner.cfg.max_batch < 2 {
        return batch;
    }
    let graph = batch[0].req.graph.clone();
    let nrows = batch[0].req.b.nrows;
    let mut i = 0;
    while i < q.deque.len() && batch.len() < inner.cfg.max_batch {
        let p = &q.deque[i];
        let rides = p.req.graph == graph
            && p.req.op == KernelOp::Spmm
            && matches!(p.req.backend, Backend::Thread)
            && p.req.b.nrows == nrows;
        if rides {
            batch.push(q.deque.remove(i).unwrap());
        } else {
            i += 1;
        }
    }
    batch
}

/// Execute a batch (size 1 = a plain request) end to end: session lookup,
/// execute, split, fulfill, record.
fn process(inner: &Inner, batch: Vec<Pending>) {
    let popped = Instant::now();
    let graph = inner.graphs.read().unwrap().get(&batch[0].req.graph).cloned();
    let Some(graph) = graph else {
        // Unreachable through try_submit (admission checks eagerly and
        // graphs are never unregistered), but never hang a ticket.
        for p in batch {
            let name = p.req.graph.clone();
            fulfill(&p.slot, Err(ServeError::UnknownGraph(name)));
        }
        return;
    };
    let key = SessionKey {
        fp: graph.fp,
        partitioner: inner.cfg.spec.partitioner,
        op: batch[0].req.op,
        backend: batch[0].req.backend.name(),
    };
    let t_plan = Instant::now();
    let (sess, _hit) = inner.registry.lock().unwrap().get_or_build(key, || {
        let mut cache = inner.cache.lock().unwrap();
        let dist = inner.cfg.spec.plan_cached(&graph.a, &mut cache);
        dist.into_session(inner.cfg.opts, true)
    });
    let plan_secs = t_plan.elapsed().as_secs_f64();

    if batch.len() == 1 {
        let p = batch.into_iter().next().unwrap();
        let queue_secs = popped.duration_since(p.enqueued).as_secs_f64();
        let t = Instant::now();
        let res = run_one(inner, &sess, &p.req);
        let exec_secs = t.elapsed().as_secs_f64();
        match res {
            Ok(r) => {
                if let Some(rec) = &r.recovery {
                    let mut st = inner.stats.lock().unwrap();
                    st.recoveries += rec.replans as u64;
                    st.recovery_secs.extend_from_slice(&rec.replan_secs);
                }
                let resp = ServeResponse {
                    dense: r.dense,
                    sparse: r.sparse,
                    stats: r.stats,
                    queue_secs,
                    plan_secs,
                    exec_secs,
                    batch_size: 1,
                    recovery: r.recovery,
                };
                record_done(inner, &[&p], popped, plan_secs, exec_secs, 1);
                fulfill(&p.slot, Ok(resp));
            }
            Err(e) => {
                inner.stats.lock().unwrap().failed += 1;
                fulfill(&p.slot, Err(ServeError::Exec(e.to_string())));
            }
        }
        return;
    }

    // Coalesced SpMM: concatenate the B columns row-major, execute once,
    // split the output columns back. Column independence makes this
    // bitwise-identical to executing each request alone.
    let n = batch.len();
    let nrows = batch[0].req.b.nrows;
    let total: usize = batch.iter().map(|p| p.req.b.ncols).sum();
    let mut combined = Dense::zeros(nrows, total);
    for r in 0..nrows {
        let row = &mut combined.data[r * total..(r + 1) * total];
        let mut off = 0;
        for p in &batch {
            let w = p.req.b.ncols;
            row[off..off + w].copy_from_slice(&p.req.b.data[r * w..(r + 1) * w]);
            off += w;
        }
    }
    let t = Instant::now();
    let res = sess.lock().unwrap().execute(&ExecRequest::spmm(&combined));
    let exec_secs = t.elapsed().as_secs_f64();
    match res {
        Ok(r) => {
            let (c, stats) = (r.dense.expect("SpMM returns dense"), r.stats);
            let out_rows = c.nrows;
            let refs: Vec<&Pending> = batch.iter().collect();
            record_done(inner, &refs, popped, plan_secs, exec_secs, n);
            let mut off = 0;
            for p in &batch {
                let w = p.req.b.ncols;
                let mut mine = Dense::zeros(out_rows, w);
                for r in 0..out_rows {
                    mine.data[r * w..(r + 1) * w]
                        .copy_from_slice(&c.data[r * total + off..r * total + off + w]);
                }
                off += w;
                let resp = ServeResponse {
                    dense: Some(mine),
                    sparse: None,
                    stats: stats.clone(),
                    queue_secs: popped.duration_since(p.enqueued).as_secs_f64(),
                    plan_secs,
                    exec_secs,
                    batch_size: n,
                    // Batches are thread-backend only; recovery is a proc
                    // backend concern.
                    recovery: None,
                };
                fulfill(&p.slot, Ok(resp));
            }
        }
        Err(e) => {
            inner.stats.lock().unwrap().failed += n as u64;
            for p in &batch {
                fulfill(&p.slot, Err(ServeError::Exec(e.to_string())));
            }
        }
    }
}

/// Execute one request on its backend: thread requests go through the warm
/// session; proc requests go through the session's frozen plan via
/// [`crate::spmm::DistSpmm::execute`], on the server's shared worker pool
/// for this fleet shape (injected unless the request brought its own), so
/// rank processes persist across requests instead of respawning.
fn run_one(
    inner: &Inner,
    sess: &Arc<Mutex<SpmmSession>>,
    req: &ServeRequest,
) -> Result<ExecResult, ExecError> {
    let missing_x =
        || ExecError::Unsupported(format!("{} requires the X operand", req.op.name()));
    let er = match req.op {
        KernelOp::Spmm => ExecRequest::spmm(&req.b),
        KernelOp::Sddmm => ExecRequest::sddmm(req.x.as_ref().ok_or_else(missing_x)?, &req.b),
        KernelOp::FusedSddmmSpmm => {
            ExecRequest::fused(req.x.as_ref().ok_or_else(missing_x)?, &req.b)
        }
    };
    match &req.backend {
        Backend::Thread => sess.lock().unwrap().execute(&er),
        Backend::Proc(popts) => {
            let mut popts = popts.clone();
            if popts.pool.is_none() {
                let topo = &inner.cfg.spec.topo;
                let key = (topo.name.clone(), topo.nranks);
                popts.pool =
                    Some(inner.pools.lock().unwrap().entry(key).or_default().clone());
            }
            let er = er
                .backend(Backend::Proc(popts))
                .opts(inner.cfg.opts)
                .fault_policy(inner.cfg.fault_policy);
            sess.lock().unwrap().dist().execute(&er)
        }
    }
}

/// Push one latency sample set per fulfilled request and bump the batch
/// counters.
fn record_done(
    inner: &Inner,
    batch: &[&Pending],
    popped: Instant,
    plan_secs: f64,
    exec_secs: f64,
    batch_size: usize,
) {
    let now = Instant::now();
    let mut st = inner.stats.lock().unwrap();
    st.completed += batch.len() as u64;
    if batch_size >= 2 {
        st.batches += 1;
        st.batched_requests += batch.len() as u64;
        st.max_batch_seen = st.max_batch_seen.max(batch_size);
    } else {
        st.max_batch_seen = st.max_batch_seen.max(1);
    }
    for p in batch {
        st.queue_secs.push(popped.duration_since(p.enqueued).as_secs_f64());
        st.plan_secs.push(plan_secs);
        st.exec_secs.push(exec_secs);
        st.total_secs.push(now.duration_since(p.enqueued).as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    fn cfg(nranks: usize) -> ServeConfig {
        let mut c = ServeConfig::new(Topology::tsubame4(nranks));
        c.workers = 0;
        c
    }

    #[test]
    fn drain_serves_a_request_bitwise() {
        let a = gen::rmat(96, 900, (0.55, 0.2, 0.19), false, 21);
        let srv = Server::new(cfg(4));
        srv.register_graph("g", a.clone());
        let mut rng = Rng::new(5);
        let b = Dense::random(96, 6, &mut rng);
        let ticket = srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap();
        assert_eq!(srv.drain_all(), 1);
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.batch_size, 1);
        let spec = PlanSpec::new(Topology::tsubame4(4));
        let (want, _) = spec.plan(&a).execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        assert_eq!(resp.into_dense(), want);
    }

    #[test]
    fn admission_is_eager_and_structured() {
        let mut c = cfg(2);
        c.queue_cap = 2;
        let a = gen::erdos_renyi(32, 32, 150, 9);
        let mut srv = Server::new(c);
        srv.register_graph("g", a);
        let b = Dense::zeros(32, 2);
        match srv.try_submit(ServeRequest::spmm("nope", b.clone())) {
            Err(ServeError::UnknownGraph(g)) => assert_eq!(g, "nope"),
            other => panic!("expected UnknownGraph, got {other:?}"),
        }
        let _t1 = srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap();
        let _t2 = srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap();
        match srv.try_submit(ServeRequest::spmm("g", b.clone())) {
            Err(ServeError::Saturated { cap }) => assert_eq!(cap, 2),
            other => panic!("expected Saturated, got {other:?}"),
        }
        // Shutdown drains the two queued requests with structured errors.
        let stats = srv.shutdown();
        assert_eq!(stats.rejected, 4);
        match _t1.wait() {
            Err(ServeError::Shutdown) => {}
            other => panic!("expected Shutdown for the drained ticket, got {other:?}"),
        }
        match srv.try_submit(ServeRequest::spmm("g", b)) {
            Err(ServeError::Shutdown) => {}
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn batcher_coalesces_same_graph_spmm_only() {
        let mut c = cfg(4);
        c.max_batch = 8;
        let a = gen::rmat(64, 500, (0.55, 0.2, 0.19), false, 22);
        let a2 = gen::rmat(64, 500, (0.55, 0.2, 0.19), false, 23);
        let srv = Server::new(c);
        srv.register_graph("g", a.clone());
        srv.register_graph("h", a2);
        let mut rng = Rng::new(6);
        let b1 = Dense::random(64, 4, &mut rng);
        let b2 = Dense::random(64, 7, &mut rng);
        let x = Dense::random(64, 4, &mut rng);
        let t1 = srv.try_submit(ServeRequest::spmm("g", b1.clone())).unwrap();
        let th = srv.try_submit(ServeRequest::spmm("h", b1.clone())).unwrap();
        let ts = srv.try_submit(ServeRequest::sddmm("g", x.clone(), x.clone())).unwrap();
        let t2 = srv.try_submit(ServeRequest::spmm("g", b2.clone())).unwrap();
        // 3 executes: {g:b1, g:b2} coalesce; h and the SDDMM run alone.
        assert_eq!(srv.drain_all(), 3);
        assert_eq!(t1.wait().unwrap().batch_size, 2);
        assert_eq!(th.wait().unwrap().batch_size, 1);
        assert_eq!(ts.wait().unwrap().batch_size, 1);
        let r2 = t2.wait().unwrap();
        assert_eq!(r2.batch_size, 2);
        // Batched result is bitwise-identical to direct execution.
        let spec = PlanSpec::new(Topology::tsubame4(4));
        let (want, _) = spec.plan(&a).execute(&ExecRequest::spmm(&b2)).unwrap().into_dense();
        let got = r2.into_dense();
        assert_eq!(got.ncols, 7);
        assert!(got
            .data
            .iter()
            .zip(want.data.iter())
            .all(|(x, y)| x.to_bits() == y.to_bits()));
        let stats = srv.stats();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.batched_requests, 2);
        assert_eq!(stats.max_batch_seen, 2);
        assert!((stats.mean_batch() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn registry_hits_and_lru_eviction_through_the_server() {
        let mut c = cfg(2);
        c.registry_cap = 2;
        let graphs: Vec<Csr> =
            (0..3).map(|i| gen::erdos_renyi(48, 48, 300, 30 + i as u64)).collect();
        let srv = Server::new(c);
        for (i, a) in graphs.iter().enumerate() {
            srv.register_graph(&format!("g{i}"), a.clone());
        }
        let b = Dense::zeros(48, 3);
        for gi in [0, 0, 1, 2, 0] {
            let t = srv.try_submit(ServeRequest::spmm(&format!("g{gi}"), b.clone())).unwrap();
            srv.drain_all();
            t.wait().unwrap();
        }
        let s = srv.stats();
        // g0 miss, g0 hit, g1 miss, g2 miss (evicts g0), g0 miss again.
        assert_eq!(s.registry_hits, 1);
        assert_eq!(s.registry_misses, 4);
        assert_eq!(s.registry_evictions, 2);
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn sddmm_and_fused_requests_serve_end_to_end() {
        let a = gen::rmat(72, 600, (0.55, 0.2, 0.19), false, 31);
        let srv = Server::new(cfg(4));
        srv.register_graph("g", a.clone());
        let mut rng = Rng::new(8);
        let x = Dense::random(72, 5, &mut rng);
        let y = Dense::random(72, 5, &mut rng);
        let ts = srv.try_submit(ServeRequest::sddmm("g", x.clone(), y.clone())).unwrap();
        let tf = srv.try_submit(ServeRequest::fused("g", x.clone(), y.clone())).unwrap();
        srv.drain_all();
        assert_eq!(ts.wait().unwrap().into_sparse(), a.sddmm(&x, &y));
        let spec = PlanSpec::new(Topology::tsubame4(4));
        let (want, _) =
            spec.plan(&a).execute(&ExecRequest::fused(&x, &y)).unwrap().into_dense();
        assert_eq!(tf.wait().unwrap().into_dense(), want);
    }

    #[test]
    fn worker_threads_serve_concurrent_clients() {
        let mut c = cfg(2);
        c.workers = 2;
        let a = gen::rmat(80, 700, (0.55, 0.2, 0.19), false, 33);
        let srv = Server::new(c);
        srv.register_graph("g", a.clone());
        let spec = PlanSpec::new(Topology::tsubame4(2));
        let dist = spec.plan(&a);
        thread::scope(|s| {
            for seed in 0..4u64 {
                let srv = &srv;
                let dist = &dist;
                s.spawn(move || {
                    let mut rng = Rng::new(100 + seed);
                    let b = Dense::random(80, 4, &mut rng);
                    let got =
                        srv.submit_wait(ServeRequest::spmm("g", b.clone())).unwrap().into_dense();
                    let (want, _) =
                        dist.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
                    assert_eq!(got, want);
                });
            }
        });
        assert_eq!(srv.stats().completed, 4);
    }
}
