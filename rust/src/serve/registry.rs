//! Capacity-bounded LRU session registry (DESIGN.md §11): the serve
//! layer's pool of warm [`SpmmSession`]s, keyed by everything that makes a
//! frozen plan reusable — the sparsity-pattern fingerprint
//! ([`crate::plan::cache::csr_fingerprint`]), the partitioner that chose
//! the row boundaries, the kernel op, and the backend tag. Tenants whose
//! requests map to the same key share one session (and therefore its plan,
//! programs, and exchange-buffer pool); when the registry is full the
//! least-recently-used session is dropped, and a later request for it
//! rebuilds through the shared [`crate::plan::cache::PlanCache`], so even
//! an evicted tenant only re-pays program derivation, not planning.
//!
//! Sessions are per-plan state only. Proc-backend rank *processes* are a
//! separate resource pooled one level up: the server keeps one
//! [`crate::runtime::multiproc::PoolHandle`] per (topology, nranks) and
//! injects it into every proc request, so evicting a session never tears
//! down a warm worker fleet — the next request on any session with the
//! same shape reuses the live connections.

use crate::exec::kernel::KernelOp;
use crate::exec::session::SpmmSession;
use crate::partition::Partitioner;
use std::sync::{Arc, Mutex};

/// Identity of a reusable session: same key ⇒ bitwise-identical plan and
/// programs, so sharing is safe across tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionKey {
    /// FNV fingerprint of the graph's sparsity pattern *and* values
    /// ([`crate::plan::cache::csr_fingerprint`]).
    pub fp: u64,
    pub partitioner: Partitioner,
    pub op: KernelOp,
    /// [`crate::spmm::Backend::name`] tag ("thread" / "proc").
    pub backend: &'static str,
}

/// LRU map from [`SessionKey`] to a shared session, bounded at `cap`
/// entries. Sessions hand out as `Arc<Mutex<_>>` so an evicted session
/// that a worker is still executing on stays alive until that call ends.
pub struct SessionRegistry {
    cap: usize,
    /// LRU order: index 0 is the least recently used, the back is the most.
    entries: Vec<(SessionKey, Arc<Mutex<SpmmSession>>)>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl SessionRegistry {
    pub fn new(cap: usize) -> SessionRegistry {
        assert!(cap >= 1, "session registry capacity must be >= 1");
        SessionRegistry { cap, entries: Vec::new(), hits: 0, misses: 0, evictions: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &SessionKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    /// Fetch the session for `key`, building and inserting it on a miss
    /// (evicting the least recently used entry at capacity). The bool is
    /// `true` on a hit. `build` runs with the registry locked by the
    /// caller, which serializes planning: two workers missing the same key
    /// never build the same session twice.
    pub fn get_or_build(
        &mut self,
        key: SessionKey,
        build: impl FnOnce() -> SpmmSession,
    ) -> (Arc<Mutex<SpmmSession>>, bool) {
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            let entry = self.entries.remove(i);
            let sess = entry.1.clone();
            self.entries.push(entry);
            self.hits += 1;
            return (sess, true);
        }
        self.misses += 1;
        let sess = Arc::new(Mutex::new(build()));
        self.entries.push((key, sess.clone()));
        if self.entries.len() > self.cap {
            self.entries.remove(0);
            self.evictions += 1;
        }
        (sess, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Strategy;
    use crate::sparse::gen;
    use crate::spmm::PlanSpec;
    use crate::topology::Topology;

    fn key(fp: u64) -> SessionKey {
        SessionKey { fp, partitioner: Partitioner::Balanced, op: KernelOp::Spmm, backend: "thread" }
    }

    fn session() -> SpmmSession {
        let a = gen::erdos_renyi(32, 32, 200, 5);
        PlanSpec::new(Topology::tsubame4(2))
            .strategy(Strategy::Row)
            .flat()
            .plan(&a)
            .into_session(crate::exec::ExecOpts::default(), true)
    }

    #[test]
    fn hit_refreshes_recency_and_eviction_is_lru() {
        let mut reg = SessionRegistry::new(2);
        let (_, hit) = reg.get_or_build(key(1), session);
        assert!(!hit);
        let (_, hit) = reg.get_or_build(key(2), session);
        assert!(!hit);
        // Touch 1 so 2 becomes the LRU entry.
        let (_, hit) = reg.get_or_build(key(1), session);
        assert!(hit);
        // Inserting 3 must evict 2, not 1.
        reg.get_or_build(key(3), session);
        assert_eq!(reg.len(), 2);
        assert!(reg.contains(&key(1)));
        assert!(!reg.contains(&key(2)));
        assert!(reg.contains(&key(3)));
        assert_eq!((reg.hits, reg.misses, reg.evictions), (1, 3, 1));
    }

    #[test]
    fn distinct_key_components_do_not_alias() {
        let mut reg = SessionRegistry::new(8);
        reg.get_or_build(key(1), session);
        let other = SessionKey { op: KernelOp::Sddmm, ..key(1) };
        let (_, hit) = reg.get_or_build(other, session);
        assert!(!hit, "kernel op is part of the identity");
        let proc = SessionKey { backend: "proc", ..key(1) };
        let (_, hit) = reg.get_or_build(proc, session);
        assert!(!hit, "backend tag is part of the identity");
        assert_eq!(reg.len(), 3);
    }
}
