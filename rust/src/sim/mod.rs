//! Event-driven α-β network simulator (DESIGN.md §1 substitution for the
//! 128-GPU testbed): per-rank, per-tier NIC ports with serialization, plus
//! per-stage compute. The simulator executes a [`SimJob`] — a sequence of
//! barrier-separated stages, each holding concurrent messages and per-rank
//! compute — and reports the timing breakdown that drives Figs. 7, 10–12.
//!
//! Cost model: a message src→dst of `bytes` on tier T occupies src's T-out
//! port and dst's T-in port for `lat(T) + bytes/bw(T)` seconds; messages
//! contending for a port serialize (longest-first list schedule). Intra and
//! inter tiers use independent ports, which is exactly the property the
//! overlapped hierarchical schedule exploits (paper §6.2).

pub mod trace;

use crate::topology::{Tier, Topology};

/// A point-to-point transfer inside one stage.
#[derive(Clone, Debug)]
pub struct SimMsg {
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
}

/// One barrier-separated stage of the job.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub name: String,
    pub msgs: Vec<SimMsg>,
    /// Per-rank compute seconds in this stage (empty = no compute).
    pub compute: Vec<f64>,
    /// If true, compute overlaps communication inside the stage; otherwise
    /// compute starts after the stage's communication completes.
    pub overlap: bool,
}

impl Stage {
    pub fn comm(name: &str, msgs: Vec<SimMsg>) -> Stage {
        Stage { name: name.into(), msgs, compute: Vec::new(), overlap: false }
    }

    pub fn compute_only(name: &str, compute: Vec<f64>) -> Stage {
        Stage { name: name.into(), msgs: Vec::new(), compute, overlap: false }
    }
}

/// A simulation job: stages run sequentially with global barriers.
#[derive(Clone, Debug, Default)]
pub struct SimJob {
    pub stages: Vec<Stage>,
}

/// Timing report for one simulated run.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// End-to-end seconds.
    pub total: f64,
    /// (stage name, stage seconds).
    pub per_stage: Vec<(String, f64)>,
    /// Seconds spent in stages that move bytes (comm-dominated stages).
    pub comm_time: f64,
    /// Seconds spent in pure-compute stages.
    pub compute_time: f64,
    pub inter_bytes: u64,
    pub intra_bytes: u64,
}

/// Simulate a job on a topology.
pub fn simulate(job: &SimJob, topo: &Topology) -> SimReport {
    let mut total = 0.0;
    let mut per_stage = Vec::new();
    let mut comm_time = 0.0;
    let mut compute_time = 0.0;
    let mut inter_bytes = 0u64;
    let mut intra_bytes = 0u64;

    for stage in &job.stages {
        let comm_dur = schedule_messages(&stage.msgs, topo);
        for m in &stage.msgs {
            match topo.tier(m.src, m.dst) {
                Tier::Inter => inter_bytes += m.bytes,
                Tier::Intra => intra_bytes += m.bytes,
            }
        }
        let max_compute = stage
            .compute
            .iter()
            .copied()
            .fold(0.0f64, f64::max);
        let dur = if stage.overlap {
            comm_dur.max(max_compute)
        } else {
            comm_dur + max_compute
        };
        if stage.msgs.is_empty() {
            compute_time += dur;
        } else {
            comm_time += dur;
        }
        per_stage.push((stage.name.clone(), dur));
        total += dur;
    }
    SimReport { total, per_stage, comm_time, compute_time, inter_bytes, intra_bytes }
}

/// Longest-processing-time list schedule of one stage's messages over the
/// per-rank, per-tier NIC ports. Returns the stage's communication makespan.
fn schedule_messages(msgs: &[SimMsg], topo: &Topology) -> f64 {
    if msgs.is_empty() {
        return 0.0;
    }
    let n = topo.nranks;
    // ports[tier][rank]: (out_free_at, in_free_at)
    let mut out_free = vec![[0.0f64; 2]; n];
    let mut in_free = vec![[0.0f64; 2]; n];
    let mut order: Vec<usize> = (0..msgs.len()).collect();
    order.sort_unstable_by(|&a, &b| msgs[b].bytes.cmp(&msgs[a].bytes));
    let mut makespan = 0.0f64;
    for &i in &order {
        let m = &msgs[i];
        let tier = topo.tier(m.src, m.dst);
        let t = tier as usize;
        let dur = topo.lat(tier) + m.bytes as f64 / topo.bw(tier);
        let start = out_free[m.src][t].max(in_free[m.dst][t]);
        let end = start + dur;
        out_free[m.src][t] = end;
        in_free[m.dst][t] = end;
        makespan = makespan.max(end);
    }
    makespan
}

/// Stage label of the flat all-to-all exchange — shared with the executor's
/// phase log so flat traces line up by name too.
pub const FLAT_STAGE: &str = "flat-alltoall";

/// Lower a flat [`crate::comm::CommPlan`] into a single all-to-all comm
/// stage (the topology-oblivious pattern of §3.2).
pub fn flat_comm_stage(
    plan: &crate::comm::CommPlan,
    n_dense: usize,
) -> Stage {
    let mut msgs = Vec::new();
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p == q {
                continue;
            }
            let bytes = plan.volume(p, q, n_dense);
            if bytes > 0 {
                msgs.push(SimMsg { src: q, dst: p, bytes });
            }
        }
    }
    Stage::comm(FLAT_STAGE, msgs)
}

/// Lower a [`crate::hierarchy::HierSchedule`] into the two overlapped
/// stages of Alg. 1. Within each stage, intra and inter messages coexist
/// and proceed on independent ports (the complementary overlap). Stage
/// names are composed from the canonical [`crate::hierarchy::phase`]
/// labels — the same names the executor's pipeline logs — so simulated and
/// executed chrome traces are comparable.
pub fn hier_comm_stages(
    sched: &crate::hierarchy::HierSchedule,
    n_dense: usize,
) -> [Stage; 2] {
    use crate::hierarchy::phase;
    let m = sched.messages();
    let row_bytes = |rows: u64| rows * n_dense as u64 * crate::comm::SZ_DT;
    let to_msgs = |v: &[crate::hierarchy::StageMsg]| -> Vec<SimMsg> {
        v.iter()
            .filter(|x| x.rows > 0)
            .map(|x| SimMsg { src: x.src, dst: x.dst, bytes: row_bytes(x.rows) })
            .collect()
    };
    let mut s1 = to_msgs(&m.s1_inter_b);
    s1.extend(to_msgs(&m.s1_intra_c));
    let mut s2 = to_msgs(&m.s2_inter_c);
    s2.extend(to_msgs(&m.s2_intra_b));
    [
        Stage::comm(&format!("{} ∥ {}", phase::S1_INTER_B, phase::S1_INTRA_C), s1),
        Stage::comm(&format!("{} ∥ {}", phase::S2_INTER_C, phase::S2_INTRA_B), s2),
    ]
}

/// Ablation control for §6.2: the same hierarchical schedule WITHOUT the
/// complementary overlap — each tier runs in its own barrier-separated
/// stage (4 stages instead of 2), named by the same phase labels.
pub fn hier_comm_stages_sequential(
    sched: &crate::hierarchy::HierSchedule,
    n_dense: usize,
) -> [Stage; 4] {
    use crate::hierarchy::phase;
    let m = sched.messages();
    let row_bytes = |rows: u64| rows * n_dense as u64 * crate::comm::SZ_DT;
    let to_msgs = |v: &[crate::hierarchy::StageMsg]| -> Vec<SimMsg> {
        v.iter()
            .filter(|x| x.rows > 0)
            .map(|x| SimMsg { src: x.src, dst: x.dst, bytes: row_bytes(x.rows) })
            .collect()
    };
    [
        Stage::comm(phase::S1_INTER_B, to_msgs(&m.s1_inter_b)),
        Stage::comm(phase::S1_INTRA_C, to_msgs(&m.s1_intra_c)),
        Stage::comm(phase::S2_INTER_C, to_msgs(&m.s2_inter_c)),
        Stage::comm(phase::S2_INTRA_B, to_msgs(&m.s2_intra_b)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::hierarchy;
    use crate::partition::{split_1d, RowPartition};
    use crate::sparse::gen;

    #[test]
    fn single_message_time() {
        let topo = Topology::flat(2, 1e9);
        let job = SimJob {
            stages: vec![Stage::comm(
                "one",
                vec![SimMsg { src: 0, dst: 1, bytes: 1_000_000 }],
            )],
        };
        let r = simulate(&job, &topo);
        // 1 MB at 1 GB/s = 1 ms (+ 5 µs latency).
        assert!((r.total - 1.005e-3).abs() < 1e-9, "{}", r.total);
    }

    #[test]
    fn same_source_serializes() {
        let topo = Topology::flat(3, 1e9);
        let msgs = vec![
            SimMsg { src: 0, dst: 1, bytes: 1_000_000 },
            SimMsg { src: 0, dst: 2, bytes: 1_000_000 },
        ];
        let r = simulate(&SimJob { stages: vec![Stage::comm("s", msgs)] }, &topo);
        assert!(r.total > 1.9e-3, "should serialize on src port: {}", r.total);
    }

    #[test]
    fn disjoint_pairs_parallel() {
        let topo = Topology::flat(4, 1e9);
        let msgs = vec![
            SimMsg { src: 0, dst: 1, bytes: 1_000_000 },
            SimMsg { src: 2, dst: 3, bytes: 1_000_000 },
        ];
        let r = simulate(&SimJob { stages: vec![Stage::comm("s", msgs)] }, &topo);
        assert!(r.total < 1.1e-3, "disjoint pairs must run concurrently: {}", r.total);
    }

    #[test]
    fn tiers_use_independent_ports() {
        // One intra + one inter message from the same source overlap.
        let topo = Topology::tsubame4(8);
        let intra = SimMsg { src: 0, dst: 1, bytes: 450_000_000 }; // ~1 ms intra
        let inter = SimMsg { src: 0, dst: 4, bytes: 6_250_000 };   // ~1 ms inter
        let r = simulate(
            &SimJob { stages: vec![Stage::comm("s", vec![intra, inter])] },
            &topo,
        );
        assert!(r.total < 1.2e-3, "tiers must overlap: {}", r.total);
        assert!(r.inter_bytes > 0 && r.intra_bytes > 0);
    }

    #[test]
    fn compute_overlap_semantics() {
        let topo = Topology::flat(2, 1e9);
        let msg = SimMsg { src: 0, dst: 1, bytes: 2_000_000 }; // 2 ms
        let mut stage = Stage::comm("s", vec![msg]);
        stage.compute = vec![1.5e-3, 0.0];
        stage.overlap = true;
        let r = simulate(&SimJob { stages: vec![stage.clone()] }, &topo);
        assert!((r.total - 2.005e-3).abs() < 1e-7, "overlap hides compute: {}", r.total);
        stage.overlap = false;
        let r2 = simulate(&SimJob { stages: vec![stage] }, &topo);
        assert!(r2.total > 3.4e-3, "no overlap adds compute: {}", r2.total);
    }

    #[test]
    fn hier_beats_flat_on_dedup_heavy_pattern() {
        // All 28 remote ranks need the same 1000 B rows from rank 0 on
        // TSUBAME: flat pushes 28 copies through rank 0's inter NIC; hier
        // pushes 6 (one per remote group) + intra fanout.
        let nranks = 32;
        let mut plan = comm::CommPlan {
            nranks,
            strategy: Strategy::Column,
            pairs: vec![vec![Default::default(); nranks]; nranks],
            block_rows: vec![2000; nranks],
        };
        for p in 1..nranks {
            plan.pairs[p][0].b_rows = (0..1000).collect();
        }
        let topo = Topology::tsubame4(nranks);
        let n_dense = 64;
        let flat = simulate(
            &SimJob { stages: vec![flat_comm_stage(&plan, n_dense)] },
            &topo,
        );
        let sched = hierarchy::build(&plan, &topo);
        let [s1, s2] = hier_comm_stages(&sched, n_dense);
        let hier = simulate(&SimJob { stages: vec![s1, s2] }, &topo);
        assert!(
            hier.total < flat.total * 0.3,
            "hier {} !< flat {}",
            hier.total,
            flat.total
        );
        assert!(hier.inter_bytes < flat.inter_bytes / 3);
    }

    #[test]
    fn realistic_plan_hier_reduces_inter_bytes() {
        let a = gen::rmat(256, 4000, (0.55, 0.2, 0.19), false, 7);
        let part = RowPartition::balanced(256, 16);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(16);
        let n_dense = 32;
        let flat = simulate(&SimJob { stages: vec![flat_comm_stage(&plan, n_dense)] }, &topo);
        let sched = hierarchy::build(&plan, &topo);
        let [s1, s2] = hier_comm_stages(&sched, n_dense);
        let hier = simulate(&SimJob { stages: vec![s1, s2] }, &topo);
        assert!(hier.inter_bytes <= flat.inter_bytes);
    }

    #[test]
    fn overlap_beats_sequential_stages() {
        // The §6.2 claim: complementary overlap (2 stages) is faster than
        // tier-serialized execution (4 stages) of the SAME message sets.
        let a = gen::rmat(512, 8000, (0.55, 0.2, 0.19), false, 9);
        let part = RowPartition::balanced(512, 16);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(16);
        let sched = hierarchy::build(&plan, &topo);
        let n_dense = 64;
        let [s1, s2] = hier_comm_stages(&sched, n_dense);
        let overlapped = simulate(&SimJob { stages: vec![s1, s2] }, &topo);
        let seq = hier_comm_stages_sequential(&sched, n_dense);
        let sequential = simulate(&SimJob { stages: seq.to_vec() }, &topo);
        assert!(
            overlapped.total < sequential.total,
            "overlap {} !< sequential {}",
            overlapped.total,
            sequential.total
        );
        assert_eq!(overlapped.inter_bytes, sequential.inter_bytes);
        assert_eq!(overlapped.intra_bytes, sequential.intra_bytes);
    }

    #[test]
    fn empty_job_zero_time() {
        let topo = Topology::flat(2, 1e9);
        let r = simulate(&SimJob::default(), &topo);
        assert_eq!(r.total, 0.0);
    }

    #[test]
    fn stage_accounting_sums() {
        let topo = Topology::flat(2, 1e9);
        let job = SimJob {
            stages: vec![
                Stage::compute_only("c", vec![1e-3, 2e-3]),
                Stage::comm("m", vec![SimMsg { src: 0, dst: 1, bytes: 1_000_000 }]),
            ],
        };
        let r = simulate(&job, &topo);
        assert_eq!(r.per_stage.len(), 2);
        assert!((r.total - (r.comm_time + r.compute_time)).abs() < 1e-12);
        assert!((r.compute_time - 2e-3).abs() < 1e-12);
    }
}
