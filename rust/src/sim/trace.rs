//! Chrome-trace (chrome://tracing / Perfetto) export of a simulated run:
//! one track per (rank, tier, direction) port, one slice per message —
//! the visual counterpart of the paper's Nsight profiling (§7.3).

use crate::sim::{SimJob, SimMsg};
use crate::topology::{Tier, Topology};
use std::fmt::Write as _;

/// One scheduled message with its simulated time window.
#[derive(Clone, Debug)]
pub struct MsgTiming {
    pub stage: usize,
    pub msg: SimMsg,
    pub tier: Tier,
    pub start: f64,
    pub end: f64,
}

/// Re-run the job's schedule, recording per-message timings.
/// (Mirrors `sim::schedule_messages` exactly; kept separate so the hot
/// simulation path stays allocation-free.)
pub fn trace(job: &SimJob, topo: &Topology) -> Vec<MsgTiming> {
    let n = topo.nranks;
    let mut timings = Vec::new();
    let mut clock = 0.0f64;
    for (stage_idx, stage) in job.stages.iter().enumerate() {
        let mut out_free = vec![[clock; 2]; n];
        let mut in_free = vec![[clock; 2]; n];
        let mut order: Vec<usize> = (0..stage.msgs.len()).collect();
        order.sort_unstable_by(|&a, &b| stage.msgs[b].bytes.cmp(&stage.msgs[a].bytes));
        let mut stage_end = clock;
        for &i in &order {
            let m = &stage.msgs[i];
            let tier = topo.tier(m.src, m.dst);
            let t = tier as usize;
            let dur = topo.lat(tier) + m.bytes as f64 / topo.bw(tier);
            let start = out_free[m.src][t].max(in_free[m.dst][t]);
            let end = start + dur;
            out_free[m.src][t] = end;
            in_free[m.dst][t] = end;
            stage_end = stage_end.max(end);
            timings.push(MsgTiming {
                stage: stage_idx,
                msg: m.clone(),
                tier,
                start,
                end,
            });
        }
        let max_compute = stage.compute.iter().copied().fold(0.0f64, f64::max);
        clock = if stage.overlap {
            stage_end.max(clock + max_compute)
        } else {
            stage_end + max_compute
        };
    }
    timings
}

/// Render timings as a Chrome trace-event JSON string (load in
/// chrome://tracing or Perfetto).
pub fn to_chrome_json(timings: &[MsgTiming], job: &SimJob) -> String {
    let mut out = String::from("[\n");
    for t in timings {
        let tier = match t.tier {
            Tier::Intra => "intra",
            Tier::Inter => "inter",
        };
        let stage_name = job
            .stages
            .get(t.stage)
            .map(|s| s.name.as_str())
            .unwrap_or("?");
        // One row per (src rank, tier): pid = src, tid = tier.
        let _ = write!(
            out,
            "{{\"name\":\"{}→{} {}B [{}]\",\"cat\":\"{}\",\"ph\":\"X\",\
             \"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{}}},\n",
            t.msg.src,
            t.msg.dst,
            t.msg.bytes,
            stage_name,
            tier,
            t.start * 1e6,
            (t.end - t.start) * 1e6,
            t.msg.src,
            t.tier as usize,
        );
    }
    if out.ends_with(",\n") {
        out.truncate(out.len() - 2);
        out.push('\n');
    }
    out.push(']');
    out
}

/// Render an executed pipeline run's per-rank phase log as chrome trace
/// events. Phase names come from [`crate::hierarchy::phase`] — the same
/// labels the simulated stages carry — so an executed trace and a
/// simulated trace of the same schedule line up side by side in Perfetto.
/// One row per rank: pid = rank, tid = 0.
pub fn exec_to_chrome_json(stats: &crate::exec::ExecStats) -> String {
    let mut out = String::from("[\n");
    for (rank, r) in stats.per_rank.iter().enumerate() {
        for p in &r.phases {
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"exec\",\"ph\":\"X\",\
                 \"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":0}},\n",
                p.name,
                p.start * 1e6,
                (p.end - p.start) * 1e6,
                rank,
            );
        }
    }
    if out.ends_with(",\n") {
        out.truncate(out.len() - 2);
        out.push('\n');
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, Stage};

    fn job() -> SimJob {
        SimJob {
            stages: vec![Stage::comm(
                "s",
                vec![
                    SimMsg { src: 0, dst: 1, bytes: 1_000_000 },
                    SimMsg { src: 0, dst: 2, bytes: 500_000 },
                    SimMsg { src: 2, dst: 3, bytes: 1_000_000 },
                ],
            )],
        }
    }

    #[test]
    fn trace_consistent_with_simulate() {
        let topo = Topology::flat(4, 1e9);
        let j = job();
        let timings = trace(&j, &topo);
        let report = simulate(&j, &topo);
        let max_end = timings.iter().fold(0.0f64, |m, t| m.max(t.end));
        assert!((max_end - report.total).abs() < 1e-12);
        assert_eq!(timings.len(), 3);
    }

    #[test]
    fn ports_never_overlap() {
        let topo = Topology::tsubame4(8);
        let j = job();
        let timings = trace(&j, &topo);
        for a in &timings {
            for b in &timings {
                if std::ptr::eq(a, b) || a.tier != b.tier {
                    continue;
                }
                let same_out = a.msg.src == b.msg.src;
                let same_in = a.msg.dst == b.msg.dst;
                if same_out || same_in {
                    let disjoint = a.end <= b.start + 1e-15 || b.end <= a.start + 1e-15;
                    assert!(disjoint, "port overlap: {a:?} vs {b:?}");
                }
            }
        }
    }

    #[test]
    fn chrome_json_parses_shapewise() {
        let topo = Topology::flat(4, 1e9);
        let j = job();
        let json = to_chrome_json(&trace(&j, &topo), &j);
        assert!(json.starts_with('['));
        assert!(json.ends_with(']'));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 3);
    }

    #[test]
    fn exec_trace_shares_phase_names_with_sim() {
        use crate::comm::{self, Strategy};
        use crate::cover::Solver;
        use crate::dense::Dense;
        use crate::exec::kernel::NativeKernel;
        use crate::partition::{split_1d, RowPartition};
        use crate::sparse::gen;
        use crate::util::rng::Rng;

        let a = gen::rmat(128, 1800, (0.55, 0.2, 0.19), false, 21);
        let part = RowPartition::balanced(128, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let sched = crate::hierarchy::build(&plan, &topo);
        let mut rng = Rng::new(9);
        let b = Dense::random(128, 8, &mut rng);
        let (_, stats) = crate::exec::run(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
        );
        let exec_json = exec_to_chrome_json(&stats);
        assert!(exec_json.starts_with('[') && exec_json.ends_with(']'));
        // The simulated stage names are composed from the same labels the
        // executor logged — every executed Alg. 1 phase name must appear in
        // one of the simulated stage titles.
        let [s1, s2] = crate::sim::hier_comm_stages(&sched, 8);
        let sim_names = format!("{} / {}", s1.name, s2.name);
        use crate::hierarchy::phase;
        for ph in [
            phase::S1_INTER_B,
            phase::S1_INTRA_C,
            phase::S2_INTER_C,
            phase::S2_INTRA_B,
        ] {
            if exec_json.contains(ph) {
                assert!(sim_names.contains(ph), "{ph} missing from sim stages");
            }
        }
    }

    #[test]
    fn stages_ordered_in_time() {
        let topo = Topology::flat(2, 1e9);
        let j = SimJob {
            stages: vec![
                Stage::comm("a", vec![SimMsg { src: 0, dst: 1, bytes: 1000 }]),
                Stage::comm("b", vec![SimMsg { src: 1, dst: 0, bytes: 1000 }]),
            ],
        };
        let t = trace(&j, &topo);
        assert!(t[0].end <= t[1].start + 1e-15);
    }
}
