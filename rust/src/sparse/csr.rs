//! COO and CSR sparse matrix formats with the operations the communication
//! planner needs: construction, conversion, transpose, block extraction,
//! row/column index sets, and SpMM against a dense matrix.

use crate::dense::Dense;

/// Coordinate-format sparse matrix. Entries need not be sorted or unique
/// until [`Coo::to_csr`] (which sorts and sums duplicates).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Coo {
        Coo {
            nrows,
            ncols,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.nrows && c < self.ncols, "entry ({r},{c}) out of bounds");
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Convert to CSR, sorting entries and summing duplicate coordinates.
    pub fn to_csr(&self) -> Csr {
        let mut order: Vec<usize> = (0..self.nnz()).collect();
        order.sort_unstable_by_key(|&i| (self.rows[i], self.cols[i]));
        let mut indptr = vec![0u64; self.nrows + 1];
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        let mut last: Option<(u32, u32)> = None;
        for &i in &order {
            let key = (self.rows[i], self.cols[i]);
            if last == Some(key) {
                *data.last_mut().unwrap() += self.vals[i];
            } else {
                indices.push(self.cols[i]);
                data.push(self.vals[i]);
                indptr[self.rows[i] as usize + 1] += 1;
                last = Some(key);
            }
        }
        for r in 0..self.nrows {
            indptr[r + 1] += indptr[r];
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices,
            data,
        }
    }
}

/// Compressed sparse row matrix (u32 column indices, f32 values).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// Length nrows+1.
    pub indptr: Vec<u64>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    pub data: Vec<f32>,
}

impl Csr {
    /// Empty matrix with no nonzeros.
    pub fn zeros(nrows: usize, ncols: usize) -> Csr {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Csr {
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=n as u64).collect(),
            indices: (0..n as u32).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r] as usize..self.indptr[r + 1] as usize]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.data[self.indptr[r] as usize..self.indptr[r + 1] as usize]
    }

    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.indptr[r + 1] - self.indptr[r]) as usize
    }

    /// Sorted unique row indices that contain at least one nonzero
    /// (`Rows(A)` in the paper's notation).
    pub fn nonempty_rows(&self) -> Vec<u32> {
        (0..self.nrows)
            .filter(|&r| self.row_nnz(r) > 0)
            .map(|r| r as u32)
            .collect()
    }

    /// Sorted unique column indices with at least one nonzero
    /// (`Cols(A)` in the paper's notation).
    pub fn nonempty_cols(&self) -> Vec<u32> {
        let mut seen = vec![false; self.ncols];
        for &c in &self.indices {
            seen[c as usize] = true;
        }
        (0..self.ncols)
            .filter(|&c| seen[c])
            .map(|c| c as u32)
            .collect()
    }

    /// Extract the sub-block of columns [c0, c1) over rows [r0, r1), with
    /// column indices re-based to c0.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Csr {
        assert!(r0 <= r1 && r1 <= self.nrows && c0 <= c1 && c1 <= self.ncols);
        let mut indptr = vec![0u64; r1 - r0 + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in r0..r1 {
            let cols = self.row_indices(r);
            let vals = self.row_values(r);
            // Columns are sorted: binary search the window.
            let lo = cols.partition_point(|&c| (c as usize) < c0);
            let hi = cols.partition_point(|&c| (c as usize) < c1);
            for k in lo..hi {
                indices.push(cols[k] - c0 as u32);
                data.push(vals[k]);
            }
            indptr[r - r0 + 1] = indices.len() as u64;
        }
        Csr {
            nrows: r1 - r0,
            ncols: c1 - c0,
            indptr,
            indices,
            data,
        }
    }

    /// Restrict to a subset of rows (given as sorted indices); returns a
    /// matrix with `rows.len()` rows in the given order.
    pub fn select_rows(&self, rows: &[u32]) -> Csr {
        let mut indptr = vec![0u64; rows.len() + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for (i, &r) in rows.iter().enumerate() {
            indices.extend_from_slice(self.row_indices(r as usize));
            data.extend_from_slice(self.row_values(r as usize));
            indptr[i + 1] = indices.len() as u64;
        }
        Csr {
            nrows: rows.len(),
            ncols: self.ncols,
            indptr,
            indices,
            data,
        }
    }

    /// Transpose (also converts CSR→CSC implicitly).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0u64; self.ncols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for c in 0..self.ncols {
            counts[c + 1] += counts[c];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0f32; self.nnz()];
        for r in 0..self.nrows {
            for (k, &c) in self.row_indices(r).iter().enumerate() {
                let dst = cursor[c as usize] as usize;
                indices[dst] = r as u32;
                data[dst] = self.row_values(r)[k];
                cursor[c as usize] += 1;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            data,
        }
    }

    /// SpMM: C = A · B (dense row-major B with `n` columns). Reference-grade
    /// serial implementation; the optimized path lives in `runtime`/L1.
    pub fn spmm(&self, b: &Dense) -> Dense {
        assert_eq!(self.ncols, b.nrows, "spmm dim mismatch");
        let mut c = Dense::zeros(self.nrows, b.ncols);
        self.spmm_acc(b, &mut c);
        c
    }

    /// SpMM accumulating into an existing dense matrix: C += A · B.
    /// Hot path (§Perf opt-2): the slice-zip inner loop in
    /// [`Csr::spmm_rows_acc`] eliminates bounds checks so LLVM
    /// autovectorizes the axpy; delegating keeps the full and tiled paths
    /// bitwise-identical by construction.
    pub fn spmm_acc(&self, b: &Dense, c: &mut Dense) {
        self.spmm_rows_acc(b, c, 0, self.nrows);
    }

    /// Row-range SpMM tile: accumulate rows `r0..r1` of A·B into the same
    /// rows of `c`. Output rows are independent in CSR SpMM and each row's
    /// nonzeros are visited in the same order as [`Csr::spmm_acc`], so
    /// running the tiles in any order is bitwise-identical to one full
    /// `spmm_acc` — the property the overlapped executor pipeline relies
    /// on when it interleaves tiles with draining its inbox.
    pub fn spmm_rows_acc(&self, b: &Dense, c: &mut Dense, r0: usize, r1: usize) {
        assert_eq!(self.ncols, b.nrows);
        assert_eq!(self.nrows, c.nrows);
        assert_eq!(b.ncols, c.ncols);
        assert!(r0 <= r1 && r1 <= self.nrows);
        for r in r0..r1 {
            let out = c.row_mut(r);
            let cols = self.row_indices(r);
            let vals = self.row_values(r);
            for (&col, &v) in cols.iter().zip(vals) {
                let brow = b.row(col as usize);
                for (o, &bv) in out.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
    }

    /// SDDMM: sampled dense-dense matrix multiplication. Returns a matrix
    /// with this pattern and values `out[k] = data[k] · ⟨x_i, y_j⟩` for the
    /// k-th stored entry (i, j). This is the serial oracle for the
    /// distributed SDDMM engine: every entry is a single dot product with a
    /// fixed accumulation order ([`Csr::sddmm_rows_into`]), so the
    /// distributed kernel — which computes each entry exactly once, at
    /// whichever rank the communication plan assigns it to — is
    /// bitwise-identical to this, even on arbitrary float inputs.
    pub fn sddmm(&self, x: &Dense, y: &Dense) -> Csr {
        let mut out = self.clone();
        self.sddmm_rows_into(x, y, &mut out.data, 0, self.nrows);
        out
    }

    /// Row-range SDDMM tile into a values buffer laid out in entry order
    /// (same indexing as `self.data`): for each stored entry (r, c) with
    /// r0 ≤ r < r1, `vals[k] = data[k] · Σ_d x[r,d]·y[c,d]`, the inner sum
    /// accumulated in ascending-d order. `x` rows are indexed by this
    /// pattern's *rows*, `y` rows by its *columns* — the executor passes
    /// compact operands whose index spaces already match the packed
    /// received payloads. Entries are written independently (no
    /// accumulation across entries), so any tiling in any order produces
    /// the same bits.
    pub fn sddmm_rows_into(&self, x: &Dense, y: &Dense, vals: &mut [f32], r0: usize, r1: usize) {
        assert_eq!(x.ncols, y.ncols, "sddmm feature-dim mismatch");
        assert!(x.nrows >= self.nrows, "sddmm x height");
        assert!(y.nrows >= self.ncols, "sddmm y height");
        assert_eq!(vals.len(), self.nnz());
        assert!(r0 <= r1 && r1 <= self.nrows);
        for r in r0..r1 {
            let xr = x.row(r);
            let (lo, hi) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            for k in lo..hi {
                let yr = y.row(self.indices[k] as usize);
                let mut dot = 0.0f32;
                for (a, b) in xr.iter().zip(yr) {
                    dot += a * b;
                }
                vals[k] = self.data[k] * dot;
            }
        }
    }

    /// Row-range SpMM tile using an override values buffer in entry order:
    /// `c[r,:] += Σ_k vals[k]·b[col_k,:]` for rows r0..r1. Visits each
    /// row's entries in the same order as [`Csr::spmm_rows_acc`], so the
    /// two are interchangeable bit-for-bit when `vals == self.data`. This
    /// is the fused SDDMM→SpMM primitive: freshly computed edge values are
    /// used as the SpMM operand without materializing a value-swapped
    /// matrix.
    pub fn spmm_vals_rows_acc(
        &self,
        vals: &[f32],
        b: &Dense,
        c: &mut Dense,
        r0: usize,
        r1: usize,
    ) {
        assert_eq!(self.ncols, b.nrows);
        assert_eq!(self.nrows, c.nrows);
        assert_eq!(b.ncols, c.ncols);
        assert_eq!(vals.len(), self.nnz());
        assert!(r0 <= r1 && r1 <= self.nrows);
        for r in r0..r1 {
            let out = c.row_mut(r);
            let (lo, hi) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            for k in lo..hi {
                let v = vals[k];
                let brow = b.row(self.indices[k] as usize);
                for (o, &bv) in out.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
    }

    /// Convert to COO.
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.nrows, self.ncols);
        for r in 0..self.nrows {
            for (k, &c) in self.row_indices(r).iter().enumerate() {
                coo.push(r, c as usize, self.row_values(r)[k]);
            }
        }
        coo
    }

    /// Structural check used by tests and after IO.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.indptr.len() == self.nrows + 1, "indptr length");
        anyhow::ensure!(
            *self.indptr.last().unwrap() as usize == self.indices.len(),
            "indptr terminal mismatch"
        );
        anyhow::ensure!(self.indices.len() == self.data.len(), "indices/data length");
        for r in 0..self.nrows {
            anyhow::ensure!(self.indptr[r] <= self.indptr[r + 1], "indptr monotone");
            let cols = self.row_indices(r);
            for w in cols.windows(2) {
                anyhow::ensure!(w[0] < w[1], "row {r} columns not strictly sorted");
            }
            if let Some(&c) = cols.last() {
                anyhow::ensure!((c as usize) < self.ncols, "column out of bounds");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [0, 3, 4]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(2, 1, 3.0);
        coo.push(2, 2, 4.0);
        coo.to_csr()
    }

    #[test]
    fn coo_to_csr_sorted() {
        let m = small();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_indices(0), &[0, 2]);
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.row_values(2), &[3.0, 4.0]);
    }

    #[test]
    fn duplicates_summed() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(0, 1, 2.5);
        let m = coo.to_csr();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_values(0), &[3.5]);
    }

    #[test]
    fn nonempty_rows_cols() {
        let m = small();
        assert_eq!(m.nonempty_rows(), vec![0, 2]);
        assert_eq!(m.nonempty_cols(), vec![0, 1, 2]);
    }

    #[test]
    fn block_extraction() {
        let m = small();
        let b = m.block(0, 2, 1, 3);
        assert_eq!(b.nrows, 2);
        assert_eq!(b.ncols, 2);
        assert_eq!(b.nnz(), 1);
        assert_eq!(b.row_indices(0), &[1]); // column 2 rebased to 1
        assert_eq!(b.row_values(0), &[2.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = small();
        let t = m.transpose();
        t.validate().unwrap();
        assert_eq!(t.nrows, 3);
        assert_eq!(t.row_indices(2), &[0, 2]);
        let back = t.transpose();
        assert_eq!(back, m);
    }

    #[test]
    fn spmm_identity() {
        let m = small();
        let b = Dense::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let c = Csr::eye(3).spmm(&b);
        assert_eq!(c.data, b.data);
        let c2 = m.spmm(&b);
        // Row 0 = 1*B[0,:] + 2*B[2,:]
        for j in 0..4 {
            assert_eq!(c2.get(0, j), b.get(0, j) + 2.0 * b.get(2, j));
            assert_eq!(c2.get(1, j), 0.0);
            assert_eq!(c2.get(2, j), 3.0 * b.get(1, j) + 4.0 * b.get(2, j));
        }
    }

    #[test]
    fn spmm_acc_accumulates() {
        let m = Csr::eye(2);
        let b = Dense::from_fn(2, 2, |i, j| (i + j) as f32);
        let mut c = Dense::from_elem(2, 2, 1.0);
        m.spmm_acc(&b, &mut c);
        assert_eq!(c.get(0, 0), 1.0);
        assert_eq!(c.get(1, 1), 3.0);
    }

    #[test]
    fn select_rows_subset() {
        let m = small();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.nrows, 2);
        assert_eq!(s.row_indices(0), &[1, 2]);
        assert_eq!(s.row_indices(1), &[0, 2]);
    }

    #[test]
    fn zeros_and_eye() {
        let z = Csr::zeros(4, 5);
        z.validate().unwrap();
        assert_eq!(z.nnz(), 0);
        let e = Csr::eye(3);
        e.validate().unwrap();
        assert_eq!(e.nnz(), 3);
        assert!(e.density() > 0.3);
    }

    #[test]
    fn tiled_spmm_bitwise_matches_full() {
        let a = crate::sparse::gen::rmat(64, 600, (0.5, 0.2, 0.2), false, 11);
        let mut rng = crate::util::rng::Rng::new(5);
        let b = Dense::random(64, 7, &mut rng);
        let want = a.spmm(&b);
        // Any tiling, any tile order: bitwise-identical accumulation.
        for tile in [1usize, 5, 17, 64] {
            let mut c = Dense::zeros(64, 7);
            let mut starts: Vec<usize> = (0..64).step_by(tile).collect();
            starts.reverse();
            for r0 in starts {
                a.spmm_rows_acc(&b, &mut c, r0, (r0 + tile).min(64));
            }
            assert_eq!(c.data, want.data, "tile {tile}");
        }
    }

    #[test]
    fn sddmm_matches_by_hand() {
        let m = small();
        let x = Dense::from_fn(3, 2, |i, j| (i * 2 + j) as f32 + 1.0);
        let y = Dense::from_fn(3, 2, |i, j| (i + j) as f32);
        let e = m.sddmm(&x, &y);
        // Structure is preserved exactly.
        assert_eq!(e.indptr, m.indptr);
        assert_eq!(e.indices, m.indices);
        // (0,0): 1·⟨x0,y0⟩ = 1·(1·0 + 2·1) = 2; (0,2): 2·⟨x0,y2⟩ = 2·(1·2+2·3) = 16
        assert_eq!(e.row_values(0), &[2.0, 16.0]);
        // (2,1): 3·⟨x2,y1⟩ = 3·(5·1+6·2) = 51; (2,2): 4·⟨x2,y2⟩ = 4·(5·2+6·3) = 112
        assert_eq!(e.row_values(2), &[51.0, 112.0]);
    }

    #[test]
    fn sddmm_tiled_bitwise_matches_full() {
        let a = crate::sparse::gen::rmat(64, 600, (0.5, 0.2, 0.2), false, 12);
        let mut rng = crate::util::rng::Rng::new(6);
        let x = Dense::random(64, 7, &mut rng);
        let y = Dense::random(64, 7, &mut rng);
        let want = a.sddmm(&x, &y);
        for tile in [1usize, 9, 64] {
            let mut vals = vec![0.0f32; a.nnz()];
            let mut starts: Vec<usize> = (0..64).step_by(tile).collect();
            starts.reverse();
            for r0 in starts {
                a.sddmm_rows_into(&x, &y, &mut vals, r0, (r0 + tile).min(64));
            }
            assert_eq!(vals, want.data, "tile {tile}");
        }
    }

    #[test]
    fn spmm_vals_matches_value_swapped_matrix() {
        // Using an override values buffer must be bitwise-identical to
        // materializing a matrix with those values and running plain SpMM —
        // the fused kernel's correctness anchor.
        let a = crate::sparse::gen::powerlaw(48, 400, 1.3, 13);
        let mut rng = crate::util::rng::Rng::new(7);
        let x = Dense::random(48, 5, &mut rng);
        let y = Dense::random(48, 5, &mut rng);
        let e = a.sddmm(&x, &y);
        let want = e.spmm(&y);
        let mut got = Dense::zeros(48, 5);
        for r0 in (0..48).step_by(11) {
            a.spmm_vals_rows_acc(&e.data, &y, &mut got, r0, (r0 + 11).min(48));
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn sddmm_empty_rows_and_empty_pattern() {
        // Rows with no stored entries contribute nothing; an all-empty
        // pattern yields an all-empty result.
        let z = Csr::zeros(4, 4);
        let x = Dense::from_elem(4, 3, 1.0);
        let e = z.sddmm(&x, &x);
        assert_eq!(e.nnz(), 0);
        let m = small(); // row 1 is structurally empty
        let e = m.sddmm(&x, &x);
        assert_eq!(e.row_nnz(1), 0);
        assert_eq!(e.row_values(0), &[3.0, 6.0]); // data · ⟨1,1⟩·3
    }

    #[test]
    fn validate_catches_unsorted() {
        let bad = Csr {
            nrows: 1,
            ncols: 3,
            indptr: vec![0, 2],
            indices: vec![2, 1],
            data: vec![1.0, 1.0],
        };
        assert!(bad.validate().is_err());
    }
}

impl Default for Csr {
    /// An empty 0×0 matrix (valid: indptr = [0]).
    fn default() -> Csr {
        Csr::zeros(0, 0)
    }
}
