//! Dataset registry mirroring the paper's Tab. 2 at laptop scale.
//!
//! Each entry maps a paper dataset to a synthetic generator preset whose
//! *pattern class* (skew, symmetry, locality) matches the original — see
//! DESIGN.md §1. `scale` multiplies the default row counts; benches use
//! scale=1, quick tests smaller.

use crate::sparse::{gen, Csr};

/// Pattern class of the original matrix (drives generator choice).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// Power-law social graph (R-MAT, symmetric-ish).
    Social,
    /// Uniform-degree mesh / road network.
    Mesh,
    /// Extremely sparse band + hubs (traffic).
    Traffic,
    /// Web graph: hubs on both row and column sides.
    Web,
    /// GNN benchmark citation graph.
    Gnn,
}

#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// Short name used throughout the paper's figures.
    pub name: &'static str,
    pub pattern: Pattern,
    /// Rows at scale = 1.0 (laptop-scale stand-in for the paper's size).
    pub base_rows: usize,
    /// Average nonzeros per row (matches the original's nnz/rows ratio).
    pub avg_nnz_per_row: f64,
    /// Whether the original is symmetric (undirected graph).
    pub symmetric: bool,
    /// Original size, for the Tab. 2 printout.
    pub paper_rows: &'static str,
    pub paper_nnz: &'static str,
    pub domain: &'static str,
}

/// The 16 datasets of Tab. 2 (13 SpMM + 3 GNN).
pub const DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "com-YT", pattern: Pattern::Social, base_rows: 1 << 14, avg_nnz_per_row: 5.5, symmetric: true, paper_rows: "1.1M", paper_nnz: "6.0M", domain: "Social" },
    DatasetSpec { name: "Pokec", pattern: Pattern::Social, base_rows: 1 << 14, avg_nnz_per_row: 19.1, symmetric: false, paper_rows: "1.6M", paper_nnz: "30.6M", domain: "Social" },
    DatasetSpec { name: "sx-SO", pattern: Pattern::Social, base_rows: 1 << 15, avg_nnz_per_row: 13.9, symmetric: false, paper_rows: "2.6M", paper_nnz: "36.2M", domain: "Q&A" },
    DatasetSpec { name: "soc-LJ", pattern: Pattern::Social, base_rows: 1 << 15, avg_nnz_per_row: 14.4, symmetric: false, paper_rows: "4.8M", paper_nnz: "69.0M", domain: "Social" },
    DatasetSpec { name: "com-LJ", pattern: Pattern::Social, base_rows: 1 << 15, avg_nnz_per_row: 17.4, symmetric: true, paper_rows: "4.0M", paper_nnz: "69.4M", domain: "Social" },
    DatasetSpec { name: "del24", pattern: Pattern::Mesh, base_rows: 1 << 16, avg_nnz_per_row: 6.0, symmetric: true, paper_rows: "16.8M", paper_nnz: "100.7M", domain: "Mesh" },
    DatasetSpec { name: "EU", pattern: Pattern::Mesh, base_rows: 1 << 16, avg_nnz_per_row: 2.1, symmetric: true, paper_rows: "50.9M", paper_nnz: "108.1M", domain: "Road" },
    DatasetSpec { name: "mawi", pattern: Pattern::Traffic, base_rows: 1 << 16, avg_nnz_per_row: 2.1, symmetric: true, paper_rows: "68.9M", paper_nnz: "143.4M", domain: "Traffic" },
    DatasetSpec { name: "Orkut", pattern: Pattern::Social, base_rows: 1 << 14, avg_nnz_per_row: 76.3, symmetric: true, paper_rows: "3.1M", paper_nnz: "234.4M", domain: "Social" },
    DatasetSpec { name: "uk-2002", pattern: Pattern::Web, base_rows: 1 << 16, avg_nnz_per_row: 16.1, symmetric: false, paper_rows: "18.5M", paper_nnz: "298.1M", domain: "Web" },
    DatasetSpec { name: "arabic", pattern: Pattern::Web, base_rows: 1 << 16, avg_nnz_per_row: 28.1, symmetric: false, paper_rows: "22.7M", paper_nnz: "640.0M", domain: "Web" },
    DatasetSpec { name: "webbase", pattern: Pattern::Web, base_rows: 1 << 17, avg_nnz_per_row: 8.6, symmetric: false, paper_rows: "118.1M", paper_nnz: "1.02B", domain: "Web" },
    DatasetSpec { name: "GAP-web", pattern: Pattern::Web, base_rows: 1 << 17, avg_nnz_per_row: 38.1, symmetric: false, paper_rows: "50.6M", paper_nnz: "1.93B", domain: "Web" },
    DatasetSpec { name: "Mag240M", pattern: Pattern::Gnn, base_rows: 1 << 17, avg_nnz_per_row: 21.3, symmetric: false, paper_rows: "121.7M", paper_nnz: "2.59B", domain: "GNN" },
    DatasetSpec { name: "Papers", pattern: Pattern::Gnn, base_rows: 1 << 17, avg_nnz_per_row: 29.1, symmetric: false, paper_rows: "111.1M", paper_nnz: "3.23B", domain: "GNN" },
    DatasetSpec { name: "IGB260M", pattern: Pattern::Gnn, base_rows: 1 << 17, avg_nnz_per_row: 13.8, symmetric: false, paper_rows: "269.3M", paper_nnz: "3.72B", domain: "GNN" },
];

/// The 13 datasets used in the SpMM comparison figures (Fig. 7–11).
pub fn spmm_datasets() -> Vec<&'static DatasetSpec> {
    DATASETS.iter().filter(|d| d.pattern != Pattern::Gnn).collect()
}

/// The 3 GNN case-study datasets (Tab. 3).
pub fn gnn_datasets() -> Vec<&'static DatasetSpec> {
    DATASETS.iter().filter(|d| d.pattern == Pattern::Gnn).collect()
}

pub fn dataset_by_name(name: &str) -> Option<&'static DatasetSpec> {
    DATASETS.iter().find(|d| d.name.eq_ignore_ascii_case(name))
}

impl DatasetSpec {
    /// Number of rows at the given scale (rounded to a power of two so the
    /// R-MAT generator and even partitioning behave).
    pub fn rows_at(&self, scale: f64) -> usize {
        let r = (self.base_rows as f64 * scale).max(64.0) as usize;
        r.next_power_of_two()
    }

    /// Generate the matrix at `scale` (1.0 = bench default). Deterministic
    /// per (dataset, scale).
    pub fn generate(&self, scale: f64) -> Csr {
        let n = self.rows_at(scale);
        let nnz = (n as f64 * self.avg_nnz_per_row) as usize;
        let seed = fxhash(self.name) ^ (scale.to_bits());
        match self.pattern {
            Pattern::Social => gen::rmat(n, nnz, (0.57, 0.19, 0.19), self.symmetric, seed),
            Pattern::Mesh => {
                // Side chosen so side² ≈ n; mesh ignores nnz target (stencil).
                let side = (n as f64).sqrt() as usize;
                gen::mesh2d(side.max(8), seed)
            }
            Pattern::Traffic => {
                let hubs = (n / 4096).max(4);
                gen::banded_hub(n, 4, hubs, 96, seed)
            }
            Pattern::Web => gen::powerlaw(n, nnz, 1.45, seed),
            Pattern::Gnn => gen::gnn_citation(n, nnz, 32, seed),
        }
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_complete() {
        assert_eq!(DATASETS.len(), 16);
        assert_eq!(spmm_datasets().len(), 13);
        assert_eq!(gnn_datasets().len(), 3);
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(dataset_by_name("MAWI").is_some());
        assert!(dataset_by_name("uk-2002").is_some());
        assert!(dataset_by_name("nope").is_none());
    }

    #[test]
    fn generate_small_all() {
        for d in DATASETS {
            let m = d.generate(0.01);
            m.validate().unwrap();
            assert!(m.nnz() > 0, "{} empty", d.name);
            assert_eq!(m.nrows, m.ncols, "{} not square", d.name);
        }
    }

    #[test]
    fn generate_deterministic() {
        let d = dataset_by_name("Pokec").unwrap();
        assert_eq!(d.generate(0.02), d.generate(0.02));
    }

    #[test]
    fn symmetric_datasets_symmetric() {
        for d in DATASETS.iter().filter(|d| d.symmetric) {
            let m = d.generate(0.01);
            let t = m.transpose();
            assert_eq!(m.indices, t.indices, "{} asymmetric", d.name);
        }
    }
}
