//! Synthetic sparse-matrix generators reproducing the *pattern classes* of
//! the paper's Tab. 2 datasets (DESIGN.md §1 explains the substitution).
//!
//! Each generator is deterministic given a seed. Values are uniform in
//! (0, 1] — communication planning only depends on structure.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// R-MAT / Kronecker-style generator: power-law degree distribution with
/// community structure — models social networks (com-YT, Pokec, soc-LJ,
/// com-LJ, Orkut) and Q&A graphs (sx-SO).
pub fn rmat(
    n: usize,
    nnz_target: usize,
    (a, b, c): (f64, f64, f64),
    symmetric: bool,
    seed: u64,
) -> Csr {
    assert!(n.is_power_of_two(), "rmat requires power-of-two n");
    let mut rng = Rng::new(seed);
    let levels = n.trailing_zeros();
    let mut coo = Coo::new(n, n);
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0, "rmat probabilities sum over 1");
    let draws = if symmetric { nnz_target / 2 } else { nnz_target };
    for _ in 0..draws.max(1) {
        let (mut r, mut col) = (0usize, 0usize);
        for _ in 0..levels {
            r <<= 1;
            col <<= 1;
            let x = rng.f64();
            if x < a {
                // top-left
            } else if x < a + b {
                col |= 1;
            } else if x < a + b + c {
                r |= 1;
            } else {
                r |= 1;
                col |= 1;
            }
        }
        let v = rng.f32() + 1e-3;
        coo.push(r, col, v);
        if symmetric && r != col {
            coo.push(col, r, v);
        }
    }
    coo.to_csr()
}

/// Erdős–Rényi uniform random matrix — models uniformly sparse patterns.
pub fn erdos_renyi(nrows: usize, ncols: usize, nnz_target: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(nrows, ncols);
    for _ in 0..nnz_target {
        coo.push(rng.below(nrows), rng.below(ncols), rng.f32() + 1e-3);
    }
    coo.to_csr()
}

/// 2-D grid mesh (5-point stencil) with rows in row-major grid order —
/// models delaunay_n24 / europe_osm style matrices: symmetric, very sparse,
/// strong locality, near-uniform degree.
pub fn mesh2d(side: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let n = side * side;
    let mut coo = Coo::new(n, n);
    for y in 0..side {
        for x in 0..side {
            let i = y * side + x;
            coo.push(i, i, 1.0 + rng.f32());
            if x + 1 < side {
                let j = i + 1;
                let v = rng.f32() + 1e-3;
                coo.push(i, j, v);
                coo.push(j, i, v);
            }
            if y + 1 < side {
                let j = i + side;
                let v = rng.f32() + 1e-3;
                coo.push(i, j, v);
                coo.push(j, i, v);
            }
        }
    }
    coo.to_csr()
}

/// Power-law web-graph generator: both in- and out-degree skewed, with hub
/// rows *and* hub columns — models uk-2002 / arabic / webbase / GAP-web.
/// This is the pattern class where the joint row-column strategy wins big
/// (paper Fig. 5 Pattern 4): hubs on both sides of the bipartite graph.
pub fn powerlaw(n: usize, nnz_target: usize, alpha: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    // Random hub permutations so hub rows and hub columns differ.
    let mut rperm: Vec<usize> = (0..n).collect();
    let mut cperm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut rperm);
    rng.shuffle(&mut cperm);
    for _ in 0..nnz_target {
        let r = rperm[rng.powerlaw(n, alpha)];
        let c = cperm[rng.powerlaw(n, alpha)];
        coo.push(r, c, rng.f32() + 1e-3);
    }
    coo.to_csr()
}

/// Banded matrix with sparse hub noise — models the mawi network-traffic
/// matrices: extremely sparse, near-diagonal band plus a handful of
/// monitor/hub nodes touching everything. Symmetric (undirected traffic).
pub fn banded_hub(n: usize, band: usize, hubs: usize, hub_degree: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 1.0);
        // A couple of near-diagonal neighbours.
        for _ in 0..2 {
            let off = 1 + rng.below(band);
            if i + off < n {
                let v = rng.f32() + 1e-3;
                coo.push(i, i + off, v);
                coo.push(i + off, i, v);
            }
        }
    }
    for _ in 0..hubs {
        let h = rng.below(n);
        for _ in 0..hub_degree {
            let t = rng.below(n);
            let v = rng.f32() + 1e-3;
            coo.push(h, t, v);
            coo.push(t, h, v);
        }
    }
    coo.to_csr()
}

/// Bipartite-ish block pattern for GNN benchmark graphs (Mag240M/IGB):
/// power-law citation structure with an added block-community overlay.
pub fn gnn_citation(n: usize, nnz_target: usize, communities: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    let comm_size = n / communities.max(1);
    let in_comm = (nnz_target as f64 * 0.6) as usize;
    for _ in 0..in_comm {
        let c0 = rng.below(communities.max(1));
        let base = c0 * comm_size;
        let r = base + rng.powerlaw(comm_size.max(1), 1.6);
        let col = base + rng.below(comm_size.max(1));
        coo.push(r.min(n - 1), col.min(n - 1), rng.f32() + 1e-3);
    }
    for _ in 0..nnz_target - in_comm {
        let r = rng.powerlaw(n, 1.8);
        let c = rng.below(n);
        coo.push(r, c, rng.f32() + 1e-3);
    }
    coo.to_csr()
}

/// The four didactic 4×4 patterns of paper Fig. 5 (over an off-diagonal
/// block). Returns (pattern_name, matrix).
pub fn fig5_patterns() -> Vec<(&'static str, Csr)> {
    let build = |entries: &[(usize, usize)]| {
        let mut coo = Coo::new(4, 4);
        for &(r, c) in entries {
            coo.push(r, c, 1.0);
        }
        coo.to_csr()
    };
    vec![
        // Pattern 1 (row-skewed): two dense rows.
        (
            "row-skewed",
            build(&[(0, 0), (0, 1), (0, 2), (0, 3), (1, 0), (1, 1), (1, 2), (1, 3)]),
        ),
        // Pattern 2 (col-skewed): two dense columns.
        (
            "col-skewed",
            build(&[(0, 0), (1, 0), (2, 0), (3, 0), (0, 1), (1, 1), (2, 1), (3, 1)]),
        ),
        // Pattern 3 (uniform): diagonal.
        ("uniform", build(&[(0, 0), (1, 1), (2, 2), (3, 3)])),
        // Pattern 4 (mixed): one dense row + one dense column.
        (
            "mixed",
            build(&[(0, 0), (0, 1), (0, 2), (0, 3), (1, 0), (2, 0), (3, 0)]),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_shape_and_determinism() {
        let a = rmat(256, 2000, (0.57, 0.19, 0.19), false, 1);
        let b = rmat(256, 2000, (0.57, 0.19, 0.19), false, 1);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert_eq!(a.nrows, 256);
        assert!(a.nnz() > 1000, "nnz {} (duplicates collapse some)", a.nnz());
    }

    #[test]
    fn rmat_is_skewed() {
        let a = rmat(512, 8000, (0.57, 0.19, 0.19), false, 2);
        let mut degs: Vec<usize> = (0..a.nrows).map(|r| a.row_nnz(r)).collect();
        degs.sort_unstable_by(|x, y| y.cmp(x));
        let top = degs[..10].iter().sum::<usize>();
        assert!(
            top * 10 > a.nnz(),
            "top-10 rows hold {top} of {} nnz — not skewed",
            a.nnz()
        );
    }

    #[test]
    fn rmat_symmetric_is_symmetric() {
        let a = rmat(128, 1500, (0.45, 0.22, 0.22), true, 3);
        let t = a.transpose();
        // Structure symmetric: same sparsity pattern.
        assert_eq!(a.indptr, t.indptr);
        assert_eq!(a.indices, t.indices);
    }

    #[test]
    fn erdos_renyi_uniformish() {
        let a = erdos_renyi(200, 300, 3000, 4);
        a.validate().unwrap();
        assert_eq!(a.nrows, 200);
        assert_eq!(a.ncols, 300);
        let max_deg = (0..a.nrows).map(|r| a.row_nnz(r)).max().unwrap();
        assert!(max_deg < 60, "uniform generator produced hub of degree {max_deg}");
    }

    #[test]
    fn mesh2d_symmetric_local() {
        let a = mesh2d(16, 5);
        a.validate().unwrap();
        assert_eq!(a.nrows, 256);
        let t = a.transpose();
        assert_eq!(a.indices, t.indices);
        // Locality: all neighbours within `side` distance.
        for r in 0..a.nrows {
            for &c in a.row_indices(r) {
                let d = (c as i64 - r as i64).unsigned_abs() as usize;
                assert!(d == 0 || d == 1 || d == 16);
            }
        }
    }

    #[test]
    fn powerlaw_hubs_on_both_sides() {
        let a = powerlaw(512, 8000, 1.5, 6);
        let rt = a.transpose();
        let max_row = (0..a.nrows).map(|r| a.row_nnz(r)).max().unwrap();
        let max_col = (0..rt.nrows).map(|r| rt.row_nnz(r)).max().unwrap();
        assert!(max_row > 50, "row hubs missing: {max_row}");
        assert!(max_col > 50, "col hubs missing: {max_col}");
    }

    #[test]
    fn banded_hub_structure() {
        let a = banded_hub(1000, 4, 5, 100, 7);
        a.validate().unwrap();
        let t = a.transpose();
        assert_eq!(a.indices, t.indices, "banded_hub must be symmetric");
        assert!(a.density() < 0.02);
    }

    #[test]
    fn fig5_pattern_shapes() {
        let ps = fig5_patterns();
        assert_eq!(ps.len(), 4);
        for (name, m) in &ps {
            m.validate().unwrap();
            assert_eq!(m.nrows, 4, "{name}");
        }
        // Pattern 1: 2 nonempty rows, 4 nonempty cols.
        assert_eq!(ps[0].1.nonempty_rows().len(), 2);
        assert_eq!(ps[0].1.nonempty_cols().len(), 4);
        // Pattern 4 (mixed): 4 rows, 4 cols, but MWVC = 2.
        assert_eq!(ps[3].1.nonempty_rows().len(), 4);
        assert_eq!(ps[3].1.nonempty_cols().len(), 4);
    }

    #[test]
    fn gnn_citation_valid() {
        let a = gnn_citation(1000, 10_000, 8, 8);
        a.validate().unwrap();
        assert!(a.nnz() > 5_000);
    }
}
