//! MatrixMarket (.mtx) reader/writer so real SuiteSparse matrices can be
//! dropped in when available, plus a compact binary cache format.

use crate::sparse::{Coo, Csr};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Read a MatrixMarket coordinate file. Supports `general` and `symmetric`
/// storage, `real` / `integer` / `pattern` fields.
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_matrix_market_from(BufReader::new(f))
}

pub fn read_matrix_market_from<R: BufRead>(mut r: R) -> Result<Csr> {
    let mut header = String::new();
    r.read_line(&mut header)?;
    let h = header.trim().to_ascii_lowercase();
    if !h.starts_with("%%matrixmarket") {
        bail!("not a MatrixMarket file: {header:?}");
    }
    let symmetric = h.contains("symmetric");
    let pattern = h.contains("pattern");
    if !h.contains("coordinate") {
        bail!("only coordinate format supported");
    }

    let mut sizes = String::new();
    loop {
        sizes.clear();
        if r.read_line(&mut sizes)? == 0 {
            bail!("unexpected EOF before size line");
        }
        if !sizes.trim_start().starts_with('%') && !sizes.trim().is_empty() {
            break;
        }
    }
    let mut it = sizes.split_whitespace();
    let nrows: usize = it.next().context("rows")?.parse()?;
    let ncols: usize = it.next().context("cols")?.parse()?;
    let nnz: usize = it.next().context("nnz")?.parse()?;

    let mut coo = Coo::new(nrows, ncols);
    let mut line = String::new();
    for k in 0..nnz {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("unexpected EOF at entry {k}");
        }
        let mut it = line.split_whitespace();
        let i: usize = it.next().context("row idx")?.parse::<usize>()? - 1;
        let j: usize = it.next().context("col idx")?.parse::<usize>()? - 1;
        let v: f32 = if pattern {
            1.0
        } else {
            it.next().context("value")?.parse()?
        };
        if i >= nrows || j >= ncols {
            bail!("entry ({},{}) out of bounds {}x{}", i + 1, j + 1, nrows, ncols);
        }
        coo.push(i, j, v);
        if symmetric && i != j {
            coo.push(j, i, v);
        }
    }
    Ok(coo.to_csr())
}

/// Write a general real MatrixMarket coordinate file.
pub fn write_matrix_market(m: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for r in 0..m.nrows {
        for (k, &c) in m.row_indices(r).iter().enumerate() {
            writeln!(w, "{} {} {}", r + 1, c + 1, m.row_values(r)[k])?;
        }
    }
    Ok(())
}

const CACHE_MAGIC: &[u8; 8] = b"SHIROCSR";

/// Write the compact binary cache (fast reload of generated datasets).
pub fn write_binary(m: &Csr, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(CACHE_MAGIC)?;
    w.write_all(&(m.nrows as u64).to_le_bytes())?;
    w.write_all(&(m.ncols as u64).to_le_bytes())?;
    w.write_all(&(m.nnz() as u64).to_le_bytes())?;
    for v in &m.indptr {
        w.write_all(&v.to_le_bytes())?;
    }
    for v in &m.indices {
        w.write_all(&v.to_le_bytes())?;
    }
    for v in &m.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub fn read_binary(path: &Path) -> Result<Csr> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != CACHE_MAGIC {
        bail!("bad cache magic");
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<std::fs::File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let nrows = read_u64(&mut r)? as usize;
    let ncols = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;
    let mut indptr = vec![0u64; nrows + 1];
    for v in indptr.iter_mut() {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        *v = u64::from_le_bytes(b);
    }
    let mut indices = vec![0u32; nnz];
    for v in indices.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *v = u32::from_le_bytes(b);
    }
    let mut data = vec![0f32; nnz];
    for v in data.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *v = f32::from_le_bytes(b);
    }
    let m = Csr {
        nrows,
        ncols,
        indptr,
        indices,
        data,
    };
    m.validate()?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use std::io::Cursor;

    #[test]
    fn parse_general() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 2\n\
                    1 1 5.0\n\
                    3 2 -1.5\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.nrows, 3);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[5.0]);
        assert_eq!(m.row_indices(2), &[1]);
    }

    #[test]
    fn parse_symmetric_expands() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 2\n\
                    1 1 1.0\n\
                    2 1 3.0\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_indices(0), &[0, 1]);
    }

    #[test]
    fn parse_pattern_field() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    2 2 1\n\
                    1 2\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.row_values(0), &[1.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_matrix_market_from(Cursor::new("hello\n")).is_err());
        let oob = "%%MatrixMarket matrix coordinate real general\n1 1 1\n2 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(oob)).is_err());
    }

    #[test]
    fn mtx_roundtrip() {
        let m = gen::erdos_renyi(20, 30, 100, 1);
        let dir = std::env::temp_dir().join("shiro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.mtx");
        write_matrix_market(&m, &p).unwrap();
        let back = read_matrix_market(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn binary_roundtrip() {
        let m = gen::rmat(64, 500, (0.5, 0.2, 0.2), false, 9);
        let dir = std::env::temp_dir().join("shiro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        write_binary(&m, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(m, back);
    }
}
