//! Sparse matrix substrate: COO/CSR formats, conversions, MatrixMarket IO,
//! synthetic workload generators, and the Tab. 2 dataset registry.

pub mod csr;
pub mod datasets;
pub mod gen;
pub mod io;
pub mod reorder;
pub mod stats;

pub use csr::{Coo, Csr};
pub use datasets::{dataset_by_name, DatasetSpec, DATASETS};
