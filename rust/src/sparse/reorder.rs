//! Matrix reordering (related-work §8.1: partitioning/reordering is
//! *orthogonal* to SHIRO's strategy optimization — "our method can be
//! applied on top of these partitioning schemes"). This module provides
//! the standard reorderings so the composition can be measured
//! (`make bench-ablation-reorder`): the paper disables reordering for
//! baseline fairness (§7.1.5); we quantify what it adds.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// Apply a symmetric permutation: B = P·A·Pᵀ, i.e. new index
/// `perm[i]` ← old index i... concretely `b[perm[i]][perm[j]] = a[i][j]`.
pub fn permute_symmetric(a: &Csr, perm: &[u32]) -> Csr {
    assert_eq!(a.nrows, a.ncols);
    assert_eq!(perm.len(), a.nrows);
    let mut coo = Coo::new(a.nrows, a.ncols);
    for r in 0..a.nrows {
        let vals = a.row_values(r);
        for (k, &c) in a.row_indices(r).iter().enumerate() {
            coo.push(perm[r] as usize, perm[c as usize] as usize, vals[k]);
        }
    }
    coo.to_csr()
}

/// Inverse of a permutation.
pub fn invert(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p as usize] = i as u32;
    }
    inv
}

/// Random symmetric permutation (destroys locality — the adversarial
/// control).
pub fn random_perm(n: usize, seed: u64) -> Vec<u32> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    Rng::new(seed).shuffle(&mut perm);
    perm
}

/// Degree-descending reordering: hubs first. Concentrates high-degree
/// vertices in the leading row blocks.
pub fn degree_order(a: &Csr) -> Vec<u32> {
    let mut order: Vec<u32> = (0..a.nrows as u32).collect();
    order.sort_by_key(|&r| std::cmp::Reverse(a.row_nnz(r as usize)));
    invert(&order)
}

/// Reverse Cuthill–McKee: BFS from a low-degree vertex, neighbours in
/// degree order, then reverse — the classic bandwidth-reducing ordering
/// (improves locality, so fewer off-diagonal nonzeros under 1D blocking).
pub fn rcm_order(a: &Csr) -> Vec<u32> {
    let n = a.nrows;
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    // Structural symmetrization for traversal.
    let at = a.transpose();
    let neighbours = |v: usize| -> Vec<u32> {
        let mut nb: Vec<u32> = a
            .row_indices(v)
            .iter()
            .chain(at.row_indices(v))
            .copied()
            .filter(|&c| c as usize != v)
            .collect();
        nb.sort_unstable_by_key(|&c| a.row_nnz(c as usize));
        nb.dedup();
        nb
    };
    let mut starts: Vec<u32> = (0..n as u32).collect();
    starts.sort_unstable_by_key(|&r| a.row_nnz(r as usize));
    for &s in &starts {
        if visited[s as usize] {
            continue;
        }
        let mut queue = std::collections::VecDeque::new();
        visited[s as usize] = true;
        queue.push_back(s);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for c in neighbours(v as usize) {
                if !visited[c as usize] {
                    visited[c as usize] = true;
                    queue.push_back(c);
                }
            }
        }
    }
    order.reverse();
    invert(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::Dense;
    use crate::sparse::gen;
    use crate::util::rng::Rng as R;

    #[test]
    fn permutation_preserves_spectrum_proxy() {
        // PAPᵀ with x permuted: (PAPᵀ)(Px) = P(Ax) — check via SpMM.
        let a = gen::rmat(64, 600, (0.5, 0.2, 0.2), false, 1);
        let perm = random_perm(64, 2);
        let b = permute_symmetric(&a, &perm);
        b.validate().unwrap();
        assert_eq!(b.nnz(), a.nnz());
        let mut rng = R::new(3);
        let x = Dense::random(64, 4, &mut rng);
        // Px
        let mut px = Dense::zeros(64, 4);
        for i in 0..64 {
            px.row_mut(perm[i] as usize).copy_from_slice(x.row(i));
        }
        let want = a.spmm(&x); // Ax
        let got = b.spmm(&px); // PAPᵀ·Px = P(Ax)
        for i in 0..64 {
            for j in 0..4 {
                assert!(
                    (got.get(perm[i] as usize, j) - want.get(i, j)).abs() < 1e-4
                );
            }
        }
    }

    #[test]
    fn invert_roundtrip() {
        let p = random_perm(100, 5);
        let inv = invert(&p);
        for i in 0..100 {
            assert_eq!(inv[p[i] as usize], i as u32);
        }
    }

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_mesh() {
        let mesh = gen::mesh2d(16, 1);
        let shuffled = permute_symmetric(&mesh, &random_perm(256, 7));
        let bandwidth = |m: &Csr| -> u64 {
            let mut bw = 0u64;
            for r in 0..m.nrows {
                for &c in m.row_indices(r) {
                    bw = bw.max((c as i64 - r as i64).unsigned_abs());
                }
            }
            bw
        };
        let rcm = permute_symmetric(&shuffled, &rcm_order(&shuffled));
        assert!(
            bandwidth(&rcm) < bandwidth(&shuffled) / 2,
            "rcm {} vs shuffled {}",
            bandwidth(&rcm),
            bandwidth(&shuffled)
        );
    }

    #[test]
    fn degree_order_fronts_hubs() {
        let a = gen::powerlaw(128, 2000, 1.4, 9);
        let d = permute_symmetric(&a, &degree_order(&a));
        let head: usize = (0..16).map(|r| d.row_nnz(r)).sum();
        let tail: usize = (112..128).map(|r| d.row_nnz(r)).sum();
        assert!(head > tail * 2, "head {head} tail {tail}");
    }

    #[test]
    fn rcm_handles_disconnected() {
        // Two disjoint cliques.
        let mut coo = Coo::new(8, 8);
        for g in 0..2 {
            for i in 0..4 {
                for j in 0..4 {
                    if i != j {
                        coo.push(g * 4 + i, g * 4 + j, 1.0);
                    }
                }
            }
        }
        let a = coo.to_csr();
        let perm = rcm_order(&a);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
    }

    use crate::sparse::Coo;
}
