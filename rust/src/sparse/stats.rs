//! Sparsity-pattern statistics used in Tab. 2 printouts and for predicting
//! the joint strategy's benefit class (paper §5.4).

use crate::sparse::Csr;

#[derive(Clone, Debug)]
pub struct MatrixStats {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    pub density: f64,
    pub avg_row_nnz: f64,
    pub max_row_nnz: usize,
    pub max_col_nnz: usize,
    /// Gini coefficient of row degrees — 0 uniform, →1 fully skewed.
    pub row_gini: f64,
    pub col_gini: f64,
    pub structurally_symmetric: bool,
}

pub fn stats(m: &Csr) -> MatrixStats {
    let row_deg: Vec<usize> = (0..m.nrows).map(|r| m.row_nnz(r)).collect();
    let mut col_deg = vec![0usize; m.ncols];
    for &c in &m.indices {
        col_deg[c as usize] += 1;
    }
    let t = m.transpose();
    let structurally_symmetric =
        m.nrows == m.ncols && m.indptr == t.indptr && m.indices == t.indices;
    MatrixStats {
        nrows: m.nrows,
        ncols: m.ncols,
        nnz: m.nnz(),
        density: m.density(),
        avg_row_nnz: m.nnz() as f64 / m.nrows.max(1) as f64,
        max_row_nnz: row_deg.iter().copied().max().unwrap_or(0),
        max_col_nnz: col_deg.iter().copied().max().unwrap_or(0),
        row_gini: gini(&row_deg),
        col_gini: gini(&col_deg),
        structurally_symmetric,
    }
}

/// Gini coefficient of a degree sequence.
pub fn gini(degrees: &[usize]) -> f64 {
    if degrees.is_empty() {
        return 0.0;
    }
    let mut d: Vec<f64> = degrees.iter().map(|&x| x as f64).collect();
    d.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = d.len() as f64;
    let total: f64 = d.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    let weighted: f64 = d
        .iter()
        .enumerate()
        .map(|(i, x)| (i as f64 + 1.0) * x)
        .sum();
    (2.0 * weighted) / (n * total) - (n + 1.0) / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn gini_uniform_zero() {
        assert!(gini(&[5, 5, 5, 5]).abs() < 1e-12);
    }

    #[test]
    fn gini_skewed_high() {
        let g = gini(&[0, 0, 0, 0, 0, 0, 0, 100]);
        assert!(g > 0.8, "gini {g}");
    }

    #[test]
    fn stats_on_mesh_vs_rmat() {
        let mesh = gen::mesh2d(20, 1);
        let rmat = gen::rmat(512, 6000, (0.57, 0.19, 0.19), false, 1);
        let sm = stats(&mesh);
        let sr = stats(&rmat);
        assert!(sm.structurally_symmetric);
        assert!(sm.row_gini < 0.2, "mesh gini {}", sm.row_gini);
        assert!(sr.row_gini > 0.4, "rmat gini {}", sr.row_gini);
    }

    #[test]
    fn stats_counts() {
        let m = gen::erdos_renyi(100, 100, 500, 2);
        let s = stats(&m);
        assert_eq!(s.nnz, m.nnz());
        assert!((s.avg_row_nnz - m.nnz() as f64 / 100.0).abs() < 1e-9);
    }
}
