//! Distributed SpMM engine: ties partitioning, cover-based planning,
//! hierarchical scheduling, the executor, and the simulator into one
//! object — the SHIRO framework's user-facing entry point.
//!
//! Plan with [`PlanSpec`], execute with [`DistSpmm::execute`] on an
//! [`ExecRequest`] — one entry point across kernel (SpMM / SDDMM / fused)
//! and backend (thread / proc). The pre-redesign `plan_*`/`execute_*`
//! constellation survives as `#[deprecated]` shims delegating here.

use crate::comm::{self, CommPlan, Strategy};
use crate::dense::Dense;
use crate::exec::{self, kernel::SpmmKernel, ExecStats};
use crate::hierarchy::{self, HierSchedule, RepSchedule};
use crate::partition::{LocalBlocks, Partitioner, RowPartition};
use crate::sim::{self, SimJob, SimReport, Stage};
use crate::sparse::Csr;
use crate::topology::Topology;

pub mod request;

pub use crate::exec::kernel::KernelOp;
pub use crate::exec::session::SpmmSession;
pub use crate::runtime::multiproc::{FaultPlan, FaultPolicy, RecoveryReport};
pub use request::{Backend, ExecError, ExecRequest, ExecResult, PlanSpec, Replicate};

/// A fully planned distributed SpMM instance. Planning (steps 1–2 of the
/// §5.1 workflow) happens once in [`PlanSpec::plan`] and is reused across
/// executions with the same sparsity pattern — `prep_secs` records the
/// one-time MWVC cost reported in Tab. 3.
pub struct DistSpmm {
    /// Row partition. For a replicated plan (`rep.is_some()`) this is the
    /// *group-level* partition: one part per replication group, matching
    /// `blocks` and `plan`; `topo` still spans the physical ranks.
    pub part: RowPartition,
    pub blocks: Vec<LocalBlocks>,
    pub plan: CommPlan,
    pub sched: Option<HierSchedule>,
    /// 1.5D replication wiring (DESIGN.md §13), `None` for the flat c=1
    /// engine. Set by [`PlanSpec::replicate`]; mutually exclusive with
    /// `sched` — the replicated executor owns its own two-level fold.
    pub rep: Option<RepSchedule>,
    pub topo: Topology,
    /// One-time preprocessing (cover solve + schedule build) seconds.
    pub prep_secs: f64,
}

impl DistSpmm {
    /// Execute one [`ExecRequest`] against this plan: the single entry
    /// point across kernels and backends.
    ///
    /// - [`KernelOp::Spmm`]: C = A·B; result in `dense`.
    /// - [`KernelOp::Sddmm`]: E = A ⊙ (X·Yᵀ) on **this SpMM plan** — the
    ///   cross-kernel reuse at the heart of DESIGN.md §9: the same B-row
    ///   covers that feed SpMM carry Y, the C covers reversed carry X, and
    ///   every edge value is computed exactly once at the rank the plan
    ///   assigned its nonzero to. Bitwise-identical to [`Csr::sddmm`];
    ///   result in `sparse`.
    /// - [`KernelOp::FusedSddmmSpmm`]: C = (A ⊙ (X·Yᵀ))·Y, GAT-style, one
    ///   exchange — no second B shipment, no edge-value gather (the strict
    ///   byte saving `ablation_fused` gates); result in `dense`.
    ///
    /// [`Backend::Thread`] runs on in-process ranks and is bit-identical
    /// across every [`exec::ExecOpts`] combination — only the schedule
    /// changes. [`Backend::Proc`] runs one OS process per rank over the
    /// socket control plane ([`crate::runtime::multiproc`]) with the same
    /// frozen per-rank programs, so results are bitwise-identical to the
    /// thread backend's; worker failures surface as
    /// [`ExecError::Rank`] instead of hanging.
    pub fn execute(&self, req: &ExecRequest) -> Result<ExecResult, ExecError> {
        let (part, plan, blocks) = (&self.part, &self.plan, &self.blocks);
        let (sched, topo) = (self.sched.as_ref(), &self.topo);
        if let Some(rep) = &self.rep {
            // Replicated (c>1) plans run the dedicated two-level executor.
            // Only SpMM has replication wiring; the SDDMM family keeps the
            // flat engine (replan at c=1 to use it).
            return match (&req.backend, req.op) {
                (Backend::Thread, KernelOp::Spmm) => {
                    let (c, st) = exec::replicate::run_replicated(
                        part, plan, blocks, rep, topo, req.b, req.kernel, &req.opts,
                    );
                    Ok(ExecResult::from_dense(c, st))
                }
                (Backend::Proc(popts), KernelOp::Spmm) => {
                    let (c, st) = crate::runtime::multiproc::run_replicated(
                        part, plan, blocks, rep, topo, req.b, &req.opts, popts,
                    )?;
                    Ok(ExecResult::from_dense(c, st))
                }
                (_, op) => Err(ExecError::Unsupported(format!(
                    "{} is not available on a replicated (c>1) plan; replan with Replicate::Factor(1)",
                    op.name()
                ))),
            };
        }
        match &req.backend {
            Backend::Thread => match req.op {
                KernelOp::Spmm => {
                    let (c, st) =
                        exec::run_with(part, plan, blocks, sched, topo, req.b, req.kernel, &req.opts);
                    Ok(ExecResult::from_dense(c, st))
                }
                KernelOp::Sddmm => {
                    let x = req.x_operand()?;
                    let (e, st) = exec::run_sddmm_with(
                        part, plan, blocks, sched, topo, x, req.b, req.kernel, &req.opts,
                    );
                    Ok(ExecResult::from_sparse(e, st))
                }
                KernelOp::FusedSddmmSpmm => {
                    let x = req.x_operand()?;
                    let (c, st) = exec::run_fused_with(
                        part, plan, blocks, sched, topo, x, req.b, req.kernel, &req.opts,
                    );
                    Ok(ExecResult::from_dense(c, st))
                }
            },
            Backend::Proc(popts) => {
                use crate::runtime::multiproc;
                let policy = req.fault_policy;
                match req.op {
                    KernelOp::Spmm => {
                        let (c, st, rec) = multiproc::run(
                            part, plan, blocks, sched, topo, req.b, &req.opts, popts, policy,
                        )?;
                        Ok(ExecResult::from_dense(c, st).with_recovery(rec))
                    }
                    KernelOp::Sddmm => {
                        let x = req.x_operand()?;
                        let (e, st, rec) = multiproc::run_sddmm(
                            part, plan, blocks, sched, topo, x, req.b, &req.opts, popts, policy,
                        )?;
                        Ok(ExecResult::from_sparse(e, st).with_recovery(rec))
                    }
                    KernelOp::FusedSddmmSpmm => {
                        let x = req.x_operand()?;
                        let (c, st, rec) = multiproc::run_fused(
                            part, plan, blocks, sched, topo, x, req.b, &req.opts, popts, policy,
                        )?;
                        Ok(ExecResult::from_dense(c, st).with_recovery(rec))
                    }
                }
            }
        }
    }

    /// Derive the plan for Aᵀ by **mirroring** this plan — no partition
    /// search, no cover re-solve, no cost-model re-evaluation. Transposing
    /// the matrix transposes each off-diagonal block, which exchanges the
    /// row/column roles of its cover: pair (q→p) of A becomes pair (p→q)
    /// of Aᵀ with `b_rows ↔ c_rows` ([`CommPlan::transpose`]), and the
    /// hierarchical schedule mirrors flow-for-flow
    /// ([`hierarchy::mirror`]). Covered (non-`full_block`) pairs keep
    /// their exact per-pair volume — and hence MWVC optimality;
    /// sparsity-oblivious `full_block` pairs swap ends
    /// (`len(q) ↔ len(p)`), preserving the total. This is what makes
    /// asymmetric operands cheap in iterative workloads: the backward Âᵀ
    /// products of GNN training reuse the forward plan's preprocessing
    /// verbatim.
    ///
    /// Requires the 1D square-SpMM setting (`split_1d` enforces a square
    /// matrix, so rows and columns share `self.part`). `prep_secs` records
    /// only the mirroring time, which is linear in the plan.
    pub fn transposed(&self) -> DistSpmm {
        let t0 = std::time::Instant::now();
        let n = self.part.nparts;
        let plan = self.plan.transpose();
        let blocks: Vec<LocalBlocks> = (0..n)
            .map(|p| LocalBlocks {
                rank: p,
                diag: self.blocks[p].diag.transpose(),
                off_diag: (0..n)
                    .map(|q| {
                        if q == p {
                            Csr::zeros(self.part.len(p), self.part.len(q))
                        } else {
                            // Aᵀ^(p,q) = (A^(q,p))ᵀ, already in local coords.
                            self.blocks[q].off_diag[p].transpose()
                        }
                    })
                    .collect(),
            })
            .collect();
        debug_assert_eq!(comm::validate::validate(&plan, &blocks), Ok(()));
        let sched = self.sched.as_ref().map(hierarchy::mirror);
        // The replica deal-out is rebuilt (not mirrored): it is a cheap
        // deterministic function of the transposed group plan.
        let rep = self.rep.as_ref().map(|r| hierarchy::build_replicated(&plan, &r.map));
        let prep_secs = t0.elapsed().as_secs_f64();
        DistSpmm {
            part: self.part.clone(),
            blocks,
            plan,
            sched,
            rep,
            topo: self.topo.clone(),
            prep_secs,
        }
    }

    /// Freeze this plan into an epoch-persistent [`SpmmSession`] (per-rank
    /// step programs, posted-payload layouts, and exchange buffers built
    /// once, reused across every `execute`). `prefers_tiles` must match
    /// the kernel the session will run with.
    pub fn into_session(self, opts: exec::ExecOpts, prefers_tiles: bool) -> SpmmSession {
        SpmmSession::new(self, opts, prefers_tiles)
    }

    /// Per-rank compute seconds for the pre-communication stage (local
    /// diagonal SpMM + row-based remote partials) and the
    /// post-communication stage (column-based remote SpMM + aggregation).
    pub fn compute_profile(&self, n_dense: usize) -> (Vec<f64>, Vec<f64>) {
        let n = self.part.nparts;
        let rate = self.topo.compute_rate;
        let launch = self.topo.kernel_launch;
        let flops = |nnz: usize| 2.0 * nnz as f64 * n_dense as f64;
        let mut pre = vec![0.0; n];
        let mut post = vec![0.0; n];
        // Launch accounting: the row-partial SpMMs for all destinations are
        // packed into one batched kernel (§5.1 step 3 "Both results are
        // packed"), as are the column-based remote SpMMs — so each stage
        // pays a constant number of launches, not one per peer.
        for r in 0..n {
            let mut f = flops(self.blocks[r].diag.nnz());
            let mut any_row = false;
            for p in 0..n {
                if p != r && self.plan.pairs[p][r].a_row_part.nnz() > 0 {
                    f += flops(self.plan.pairs[p][r].a_row_part.nnz());
                    any_row = true;
                }
            }
            pre[r] = f / rate + (1 + usize::from(any_row)) as f64 * launch;
            let mut f = 0.0;
            let mut any_col = false;
            for q in 0..n {
                if q != r && self.plan.pairs[r][q].a_col_part.nnz() > 0 {
                    f += flops(self.plan.pairs[r][q].a_col_part.nnz());
                    any_col = true;
                }
            }
            post[r] = f / rate + usize::from(any_col) as f64 * launch;
        }
        (pre, post)
    }

    /// Build the simulation job (used by the figure benches at 128 ranks).
    /// Stage names use the canonical [`crate::hierarchy::phase`] labels,
    /// matching the executor's phase log ("compute: local" covers the
    /// diagonal block plus the row-based remote partials; "compute:
    /// remote" the column-based remote SpMMs plus aggregation).
    pub fn sim_job(&self, n_dense: usize) -> SimJob {
        use crate::hierarchy::phase;
        let (pre, post) = self.compute_profile(n_dense);
        let mut stages = vec![Stage::compute_only(phase::COMPUTE_LOCAL, pre)];
        match &self.sched {
            None => stages.push(sim::flat_comm_stage(&self.plan, n_dense)),
            Some(s) => {
                let [s1, s2] = sim::hier_comm_stages(s, n_dense);
                stages.push(s1);
                stages.push(s2);
            }
        }
        stages.push(Stage::compute_only(phase::COMPUTE_REMOTE, post));
        SimJob { stages }
    }

    /// Simulate one SpMM on the planned topology.
    pub fn simulate(&self, n_dense: usize) -> SimReport {
        sim::simulate(&self.sim_job(n_dense), &self.topo)
    }
}

/// Legacy pre-`ExecRequest` surface, kept as thin shims. Every method
/// delegates to [`PlanSpec`] / [`DistSpmm::execute`] and is pinned
/// bitwise-identical to its replacement by `tests/api_compat.rs`.
impl DistSpmm {
    /// Plan a distributed SpMM of `a` over `topo.nranks` ranks.
    #[deprecated(note = "use PlanSpec::new(topo).strategy(..).hierarchical(..).plan(a)")]
    pub fn plan(a: &Csr, strategy: Strategy, topo: Topology, hierarchical: bool) -> DistSpmm {
        PlanSpec::new(topo).strategy(strategy).hierarchical(hierarchical).plan(a)
    }

    /// [`DistSpmm::plan`] with explicit planner knobs.
    #[deprecated(note = "use PlanSpec::new(topo).params(..).plan(a)")]
    pub fn plan_with_params(
        a: &Csr,
        strategy: Strategy,
        topo: Topology,
        hierarchical: bool,
        params: &crate::plan::PlanParams,
    ) -> DistSpmm {
        PlanSpec::new(topo)
            .strategy(strategy)
            .hierarchical(hierarchical)
            .params(params.clone())
            .plan(a)
    }

    /// [`DistSpmm::plan_with_params`] with an explicit [`Partitioner`].
    #[deprecated(note = "use PlanSpec::new(topo).partitioner(..).plan(a)")]
    pub fn plan_partitioned(
        a: &Csr,
        strategy: Strategy,
        topo: Topology,
        hierarchical: bool,
        params: &crate::plan::PlanParams,
        partitioner: Partitioner,
    ) -> DistSpmm {
        PlanSpec::new(topo)
            .strategy(strategy)
            .hierarchical(hierarchical)
            .params(params.clone())
            .partitioner(partitioner)
            .plan(a)
    }

    /// Adaptive planning through a [`crate::plan::cache::PlanCache`].
    #[deprecated(note = "use PlanSpec::new(topo).strategy(Strategy::Adaptive).plan_cached(a, cache)")]
    pub fn plan_adaptive_cached(
        a: &Csr,
        topo: Topology,
        hierarchical: bool,
        params: &crate::plan::PlanParams,
        cache: &mut crate::plan::cache::PlanCache,
    ) -> DistSpmm {
        PlanSpec::new(topo)
            .strategy(Strategy::Adaptive)
            .hierarchical(hierarchical)
            .params(params.clone())
            .plan_cached(a, cache)
    }

    /// Mirror this plan for Aᵀ.
    #[deprecated(note = "renamed to DistSpmm::transposed")]
    pub fn plan_transpose(&self) -> DistSpmm {
        self.transposed()
    }

    /// C = A·B with explicit executor options.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::spmm(b).kernel(k).opts(o))")]
    pub fn execute_with(
        &self,
        b: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        opts: &exec::ExecOpts,
    ) -> (Dense, ExecStats) {
        self.execute(&ExecRequest::spmm(b).kernel(kernel).opts(*opts))
            .expect("thread backend is infallible")
            .into_dense()
    }

    /// E = A ⊙ (X·Yᵀ) with default options.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::sddmm(x, y).kernel(k))")]
    pub fn execute_sddmm(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Csr, ExecStats) {
        self.execute(&ExecRequest::sddmm(x, y).kernel(kernel))
            .expect("thread backend is infallible")
            .into_sparse()
    }

    /// [`DistSpmm::execute_sddmm`] with explicit executor options.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::sddmm(x, y).kernel(k).opts(o))")]
    pub fn execute_sddmm_with(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        opts: &exec::ExecOpts,
    ) -> (Csr, ExecStats) {
        self.execute(&ExecRequest::sddmm(x, y).kernel(kernel).opts(*opts))
            .expect("thread backend is infallible")
            .into_sparse()
    }

    /// Fused SDDMM→SpMM with default options.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::fused(x, y).kernel(k))")]
    pub fn execute_fused(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Dense, ExecStats) {
        self.execute(&ExecRequest::fused(x, y).kernel(kernel))
            .expect("thread backend is infallible")
            .into_dense()
    }

    /// [`DistSpmm::execute_fused`] with explicit executor options.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::fused(x, y).kernel(k).opts(o))")]
    pub fn execute_fused_with(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        opts: &exec::ExecOpts,
    ) -> (Dense, ExecStats) {
        self.execute(&ExecRequest::fused(x, y).kernel(kernel).opts(*opts))
            .expect("thread backend is infallible")
            .into_dense()
    }

    /// C = A·B on the multi-process backend.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::spmm(b).backend(Backend::Proc(..)))")]
    pub fn execute_proc(
        &self,
        b: &Dense,
        opts: &exec::ExecOpts,
        popts: &crate::runtime::multiproc::ProcOpts,
    ) -> Result<(Dense, ExecStats), crate::runtime::multiproc::RankFailure> {
        let req = ExecRequest::spmm(b).opts(*opts).backend(Backend::Proc(popts.clone()));
        match self.execute(&req) {
            Ok(r) => Ok(r.into_dense()),
            Err(ExecError::Rank(f)) => Err(f),
            Err(e) => panic!("proc SpMM cannot fail with {e}"),
        }
    }

    /// Fused SDDMM→SpMM on the multi-process backend.
    #[deprecated(note = "use DistSpmm::execute(&ExecRequest::fused(x, y).backend(Backend::Proc(..)))")]
    pub fn execute_fused_proc(
        &self,
        x: &Dense,
        y: &Dense,
        opts: &exec::ExecOpts,
        popts: &crate::runtime::multiproc::ProcOpts,
    ) -> Result<(Dense, ExecStats), crate::runtime::multiproc::RankFailure> {
        let req = ExecRequest::fused(x, y).opts(*opts).backend(Backend::Proc(popts.clone()));
        match self.execute(&req) {
            Ok(r) => Ok(r.into_dense()),
            Err(ExecError::Rank(f)) => Err(f),
            Err(e) => panic!("proc fused cannot fail with {e}"),
        }
    }
}

/// Distributed SDDMM engine as a newtype over [`DistSpmm`]. Superseded by
/// [`ExecRequest::sddmm`] on [`DistSpmm::execute`] — the plan *is* an SpMM
/// plan, so the wrapper only renamed methods.
#[deprecated(note = "use DistSpmm::execute with ExecRequest::sddmm / ExecRequest::fused")]
pub struct DistSddmm(pub DistSpmm);

#[allow(deprecated)]
impl DistSddmm {
    /// Plan a distributed SDDMM of `a`'s pattern over `topo.nranks` ranks.
    pub fn plan(a: &Csr, strategy: Strategy, topo: Topology, hierarchical: bool) -> DistSddmm {
        DistSddmm(PlanSpec::new(topo).strategy(strategy).hierarchical(hierarchical).plan(a))
    }

    /// Reuse an existing SpMM plan for SDDMM — zero additional
    /// preprocessing.
    pub fn from_spmm(dist: DistSpmm) -> DistSddmm {
        DistSddmm(dist)
    }

    /// The underlying shared plan.
    pub fn dist(&self) -> &DistSpmm {
        &self.0
    }

    /// Execute E = A ⊙ (X·Yᵀ); bitwise-identical to [`Csr::sddmm`].
    pub fn execute(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Csr, ExecStats) {
        self.0.execute_sddmm(x, y, kernel)
    }

    /// [`DistSddmm::execute`] with explicit executor options.
    pub fn execute_with(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        opts: &exec::ExecOpts,
    ) -> (Csr, ExecStats) {
        self.0.execute_sddmm_with(x, y, kernel, opts)
    }

    /// Execute the fused SDDMM→SpMM kernel on the shared plan.
    pub fn execute_fused(
        &self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Dense, ExecStats) {
        self.0.execute_fused(x, y, kernel)
    }

    /// Freeze into a kernel-generic [`SpmmSession`].
    pub fn into_session(self, opts: exec::ExecOpts, prefers_tiles: bool) -> SpmmSession {
        self.0.into_session(opts, prefers_tiles)
    }
}

/// Serial reference: C = A·B on one rank (the oracle for all tests).
pub fn serial_reference(a: &Csr, b: &Dense) -> Dense {
    a.spmm(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cover::Solver;
    use crate::exec::kernel::NativeKernel;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    fn spec(nranks: usize) -> PlanSpec {
        PlanSpec::new(Topology::tsubame4(nranks))
    }

    #[test]
    fn plan_execute_simulate_roundtrip() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 1);
        let d = spec(8).plan(&a);
        assert!(d.prep_secs >= 0.0);
        let mut rng = Rng::new(1);
        let b = Dense::random(128, 16, &mut rng);
        let (c, stats) = d.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        assert!(serial_reference(&a, &b).diff_norm(&c) < 1e-3);
        assert!(stats.wall_secs > 0.0);
        let rep = d.simulate(16);
        assert!(rep.total > 0.0);
        assert_eq!(rep.per_stage.len(), 4); // pre, stage I, stage II, post
    }

    #[test]
    fn flat_sim_has_three_stages() {
        let a = gen::erdos_renyi(64, 64, 600, 2);
        let d = spec(4).strategy(Strategy::Column).flat().plan(&a);
        let rep = d.simulate(32);
        assert_eq!(rep.per_stage.len(), 3);
    }

    #[test]
    fn joint_sim_no_slower_than_column_inter_bytes() {
        let a = gen::powerlaw(256, 4000, 1.4, 3);
        let joint = spec(16).strategy(Strategy::Joint(Solver::Koenig)).plan(&a);
        let col = spec(16).strategy(Strategy::Column).plan(&a);
        let jr = joint.simulate(32);
        let cr = col.simulate(32);
        assert!(jr.inter_bytes <= cr.inter_bytes);
    }

    #[test]
    fn adaptive_plan_executes_and_simulates() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 9);
        let d = spec(8).strategy(Strategy::Adaptive).plan(&a);
        assert_eq!(d.plan.strategy, Strategy::Adaptive);
        let mut rng = Rng::new(3);
        let b = Dense::random(128, 16, &mut rng);
        let (c, _) = d.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        assert!(serial_reference(&a, &b).diff_norm(&c) < 1e-3);
        assert!(d.simulate(16).total > 0.0);
    }

    #[test]
    fn adaptive_cached_matches_uncached() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 10);
        let mut cache = crate::plan::cache::PlanCache::in_memory();
        let d1 = spec(8).strategy(Strategy::Adaptive).plan_cached(&a, &mut cache);
        let d2 = spec(8).strategy(Strategy::Adaptive).plan_cached(&a, &mut cache);
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(d1.plan.total_volume(32), d2.plan.total_volume(32));
        let mut rng = Rng::new(4);
        let b = Dense::random(128, 8, &mut rng);
        let (c, _) = d2.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        assert!(serial_reference(&a, &b).diff_norm(&c) < 1e-3);
    }

    #[test]
    fn execute_with_options_bit_identical() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 15);
        let d = spec(8).plan(&a);
        let mut rng = Rng::new(7);
        let b = Dense::random(128, 8, &mut rng);
        let (c_on, _) = d.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        let (c_off, off_stats) = d
            .execute(&ExecRequest::spmm(&b).opts(crate::exec::ExecOpts::sequential()))
            .unwrap()
            .into_dense();
        assert_eq!(c_on.data, c_off.data, "overlap option changed the bits");
        assert_eq!(off_stats.overlap_window().overlapped_bytes, 0);
    }

    #[test]
    fn plan_partitioned_exact_for_every_partitioner() {
        // rmat's top-left bias makes equal-row partitions unfair; every
        // partitioner must still produce the exact answer through the
        // whole plan → hierarchy → exec → sim stack.
        let a = gen::rmat(256, 3000, (0.6, 0.18, 0.18), false, 21);
        let mut rng = Rng::new(9);
        let b = Dense::random(256, 8, &mut rng);
        let want = serial_reference(&a, &b);
        for partitioner in crate::partition::Partitioner::ALL {
            let d = spec(8).partitioner(partitioner).plan(&a);
            assert_eq!(d.part.nparts, 8);
            let (c, _) = d.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
            assert!(
                want.diff_norm(&c) < 1e-3,
                "{} produced a wrong result",
                partitioner.name()
            );
            assert!(d.simulate(8).total > 0.0, "{} sim failed", partitioner.name());
        }
        // The load-aware splits actually change the boundaries here.
        let bal = spec(8).flat().partitioner(Partitioner::Balanced).plan(&a);
        let nnz = spec(8).flat().partitioner(Partitioner::NnzBalanced).plan(&a);
        assert_ne!(bal.part.starts, nnz.part.starts);
        assert!(
            crate::partition::max_rank_nnz(&a, &nnz.part)
                <= crate::partition::max_rank_nnz(&a, &bal.part)
        );
    }

    #[test]
    fn transposed_executes_a_transpose_times_b() {
        // Asymmetric matrix: the mirrored plan must compute Aᵀ·B (not
        // A·B), through both flat and hierarchical routing, and preserve
        // the forward plan's total volume exactly.
        let a = gen::rmat(128, 1500, (0.6, 0.22, 0.12), false, 31);
        let at = a.transpose();
        let mut rng = Rng::new(11);
        let b = Dense::random(128, 16, &mut rng);
        let want = at.spmm(&b);
        for hier in [false, true] {
            let fwd = spec(8).hierarchical(hier).plan(&a);
            let bwd = fwd.transposed();
            assert_eq!(bwd.plan.total_volume(16), fwd.plan.total_volume(16));
            assert_eq!(bwd.sched.is_some(), hier);
            let (got, _) = bwd.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
            assert!(
                want.diff_norm(&got) < 1e-3,
                "hier={hier}: mirrored plan computed the wrong product"
            );
            // And the forward plan still computes A·B.
            let (fgot, _) = fwd.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
            assert!(a.spmm(&b).diff_norm(&fgot) < 1e-3);
        }
    }

    #[test]
    fn transposed_simulates_and_sessions() {
        let a = gen::powerlaw(256, 4000, 1.4, 32);
        let fwd = spec(8).strategy(Strategy::Adaptive).plan(&a);
        let bwd = fwd.transposed();
        assert!(bwd.simulate(16).total > 0.0);
        let mut rng = Rng::new(12);
        let b = Dense::random(256, 8, &mut rng);
        let want = a.transpose().spmm(&b);
        let mut session = bwd.into_session(crate::exec::ExecOpts::default(), true);
        for _ in 0..2 {
            let (got, _) = session.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
            assert!(want.diff_norm(&got) < 1e-3);
        }
        assert!(session.amortization().steady_state());
    }

    #[test]
    fn one_plan_serves_sddmm_and_fused_end_to_end() {
        let a = gen::powerlaw(256, 3500, 1.4, 41);
        let mut rng = Rng::new(13);
        let x = Dense::random(256, 8, &mut rng);
        let y = Dense::random(256, 8, &mut rng);
        let want = a.sddmm(&x, &y);
        for hier in [false, true] {
            let d = spec(8).hierarchical(hier).plan(&a);
            let (e, sddmm_stats) = d.execute(&ExecRequest::sddmm(&x, &y)).unwrap().into_sparse();
            assert_eq!(e, want, "hier={hier}: distributed SDDMM != oracle");
            // One plan, two kernels, identical B-side traffic.
            let (_, spmm_stats) = d.execute(&ExecRequest::spmm(&y)).unwrap().into_dense();
            assert_eq!(
                spmm_stats.measured_b_volume(),
                sddmm_stats.measured_b_volume(),
                "hier={hier}"
            );
            // Fused output equals SDDMM-then-serial-SpMM numerically.
            let (c, _) = d.execute(&ExecRequest::fused(&x, &y)).unwrap().into_dense();
            let ref_c = want.spmm(&y);
            assert!(ref_c.diff_norm(&c) / (ref_c.max_abs() as f64 + 1e-30) < 1e-3);
        }
    }

    #[test]
    fn adaptive_plan_serves_sddmm_too() {
        // The kernel abstraction must compose with the per-pair adaptive
        // compiler: whatever shape each pair chose, SDDMM reuses it.
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 43);
        let d = spec(8).strategy(Strategy::Adaptive).plan(&a);
        let mut rng = Rng::new(14);
        let x = Dense::random(128, 8, &mut rng);
        let y = Dense::random(128, 8, &mut rng);
        let (e, _) = d
            .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
            .unwrap()
            .into_sparse();
        assert_eq!(e, a.sddmm(&x, &y));
    }

    #[test]
    fn compute_profile_nonnegative_and_scaled() {
        let a = gen::rmat(128, 2000, (0.5, 0.2, 0.2), false, 4);
        let d = spec(8).flat().plan(&a);
        let (pre32, _) = d.compute_profile(32);
        let (pre64, _) = d.compute_profile(64);
        for (a32, a64) in pre32.iter().zip(&pre64) {
            assert!(*a32 > 0.0);
            assert!(a64 > a32, "compute must grow with N");
        }
    }
}
