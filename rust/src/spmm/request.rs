//! The unified execution API (DESIGN.md §11): one request type for every
//! kernel × backend × option combination, and one builder for every way of
//! planning.
//!
//! The legacy surface grew combinatorially — five `plan_*` constructors
//! and ~10 `execute_*` variants across [`DistSpmm`]/`DistSddmm` — which no
//! serving front-end can sit on cleanly. [`ExecRequest`] collapses the
//! execute axis: kernel op ([`KernelOp`]), backend ([`Backend`]), executor
//! options, operands, and compute kernel travel together, and
//! [`DistSpmm::execute`] / [`crate::exec::session::SpmmSession::execute`]
//! are the only entry points. [`PlanSpec`] collapses the plan axis:
//! strategy, topology, hierarchy, planner params, and partitioner are
//! builder fields with the same defaults the old constructors hardcoded.
//! The legacy methods survive as `#[deprecated]` shims delegating here,
//! pinned bitwise-identical by `tests/api_compat.rs`.

use crate::comm::Strategy;
use crate::cover::Solver;
use crate::dense::Dense;
use crate::exec::kernel::{KernelOp, NativeKernel, SpmmKernel};
use crate::exec::{ExecOpts, ExecStats};
use crate::partition::Partitioner;
use crate::plan::cache::PlanCache;
use crate::plan::PlanParams;
use crate::runtime::multiproc::{FaultPolicy, ProcOpts, RankFailure, RecoveryReport};
use crate::sparse::Csr;
use crate::topology::Topology;
use std::fmt;

/// Where a request runs: in-process rank threads (the default and the
/// differential oracle) or one OS process per rank over the socket control
/// plane ([`crate::runtime::multiproc`]).
#[derive(Clone, Debug, Default)]
pub enum Backend {
    #[default]
    Thread,
    Proc(ProcOpts),
}

impl Backend {
    /// Default proc backend (30 s failure timeout, `current_exe` workers).
    pub fn proc() -> Backend {
        Backend::Proc(ProcOpts::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Thread => "thread",
            Backend::Proc(_) => "proc",
        }
    }
}

/// One execution request against a planned [`DistSpmm`] or a session:
/// which kernel, which operands, how to schedule it, and where to run it.
///
/// Operand convention: `b` is the SpMM dense operand B — for the
/// SDDMM-family kernels it carries Y (the operand that moves along the B
/// covers) and `x` carries X. Construct with [`ExecRequest::spmm`] /
/// [`ExecRequest::sddmm`] / [`ExecRequest::fused`] and chain the setters.
///
/// `opts` applies to one-shot [`DistSpmm::execute`] calls; sessions own
/// their scheduling options ([`SpmmSession::set_opts`]) because the frozen
/// programs depend on them. `kernel` applies to the thread backend; proc
/// workers always run [`NativeKernel`] (trait objects don't cross the
/// process boundary).
///
/// [`DistSpmm::execute`]: crate::spmm::DistSpmm::execute
/// [`SpmmSession::set_opts`]: crate::exec::session::SpmmSession::set_opts
pub struct ExecRequest<'a> {
    pub op: KernelOp,
    /// X operand (SDDMM-family kernels only).
    pub x: Option<&'a Dense>,
    /// B operand (SpMM), or Y (SDDMM-family).
    pub b: &'a Dense,
    pub opts: ExecOpts,
    pub backend: Backend,
    pub kernel: &'a (dyn SpmmKernel + Sync),
    /// What to do when a worker process dies mid-step (proc backend only;
    /// thread ranks share an address space and cannot fail independently).
    pub fault_policy: FaultPolicy,
}

impl<'a> ExecRequest<'a> {
    /// C = A·B.
    pub fn spmm(b: &'a Dense) -> ExecRequest<'a> {
        ExecRequest {
            op: KernelOp::Spmm,
            x: None,
            b,
            opts: ExecOpts::default(),
            backend: Backend::Thread,
            kernel: &NativeKernel,
            fault_policy: FaultPolicy::Fail,
        }
    }

    /// E = A ⊙ (X·Yᵀ).
    pub fn sddmm(x: &'a Dense, y: &'a Dense) -> ExecRequest<'a> {
        ExecRequest { op: KernelOp::Sddmm, x: Some(x), ..ExecRequest::spmm(y) }
    }

    /// C = (A ⊙ (X·Yᵀ))·Y, one exchange.
    pub fn fused(x: &'a Dense, y: &'a Dense) -> ExecRequest<'a> {
        ExecRequest { op: KernelOp::FusedSddmmSpmm, x: Some(x), ..ExecRequest::spmm(y) }
    }

    /// Executor scheduling options (overlap, tile height, worker cap).
    pub fn opts(mut self, opts: ExecOpts) -> ExecRequest<'a> {
        self.opts = opts;
        self
    }

    /// Execution backend (thread ranks vs worker processes).
    pub fn backend(mut self, backend: Backend) -> ExecRequest<'a> {
        self.backend = backend;
        self
    }

    /// Compute kernel implementation (thread backend only).
    pub fn kernel(mut self, kernel: &'a (dyn SpmmKernel + Sync)) -> ExecRequest<'a> {
        self.kernel = kernel;
        self
    }

    /// Crash handling on the proc backend: [`FaultPolicy::Fail`] (default)
    /// surfaces a [`RankFailure`]; [`FaultPolicy::Recover`] replans over
    /// the survivors and replays the step (DESIGN.md §12).
    pub fn fault_policy(mut self, policy: FaultPolicy) -> ExecRequest<'a> {
        self.fault_policy = policy;
        self
    }

    /// The X operand, or a structured error for requests that need one but
    /// were built by hand without it.
    pub(crate) fn x_operand(&self) -> Result<&'a Dense, ExecError> {
        self.x.ok_or_else(|| {
            ExecError::Unsupported(format!("{} requires the X operand", self.op.name()))
        })
    }
}

/// The outcome of one [`ExecRequest`]: exactly one of `dense` (SpMM,
/// fused) or `sparse` (SDDMM) is set, plus the measured traffic stats.
#[derive(Debug)]
pub struct ExecResult {
    pub dense: Option<Dense>,
    pub sparse: Option<Csr>,
    pub stats: ExecStats,
    /// Set iff the proc backend lost at least one worker and recovered
    /// under [`FaultPolicy::Recover`]; `None` on every clean run.
    pub recovery: Option<RecoveryReport>,
}

impl ExecResult {
    pub(crate) fn from_dense(c: Dense, stats: ExecStats) -> ExecResult {
        ExecResult { dense: Some(c), sparse: None, stats, recovery: None }
    }

    pub(crate) fn from_sparse(e: Csr, stats: ExecStats) -> ExecResult {
        ExecResult { dense: None, sparse: Some(e), stats, recovery: None }
    }

    pub(crate) fn with_recovery(mut self, recovery: Option<RecoveryReport>) -> ExecResult {
        self.recovery = recovery;
        self
    }

    /// The dense output and stats; panics on an SDDMM result.
    pub fn into_dense(self) -> (Dense, ExecStats) {
        (self.dense.expect("request produced a sparse result, not dense"), self.stats)
    }

    /// The sparse output and stats; panics on a dense-output result.
    pub fn into_sparse(self) -> (Csr, ExecStats) {
        (self.sparse.expect("request produced a dense result, not sparse"), self.stats)
    }
}

/// Why an [`ExecRequest`] could not produce a result.
#[derive(Debug)]
pub enum ExecError {
    /// A worker process died or misbehaved (proc backend).
    Rank(RankFailure),
    /// The request is not executable as specified (missing operand,
    /// backend the entry point cannot serve).
    Unsupported(String),
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::Rank(r) => write!(f, "{r}"),
            ExecError::Unsupported(m) => write!(f, "unsupported request: {m}"),
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Rank(r) => Some(r),
            ExecError::Unsupported(_) => None,
        }
    }
}

impl From<RankFailure> for ExecError {
    fn from(r: RankFailure) -> ExecError {
        ExecError::Rank(r)
    }
}

/// Replication factor for the 1.5D decomposition (DESIGN.md §13): ranks
/// are grouped into `nranks/c` replication groups of `c` consecutive
/// ranks, A is replicated within each group, and the group's inter-group
/// traffic is dealt out across the members. `Factor(1)` is the flat 1D
/// engine — the default, and bitwise-identical to the pre-replication
/// planner. `Auto` searches [`crate::plan::REPLICATION_CANDIDATES`] with
/// the α-β model ([`crate::plan::choose_replication`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Replicate {
    Factor(usize),
    Auto,
}

impl Default for Replicate {
    fn default() -> Replicate {
        Replicate::Factor(1)
    }
}

/// Builder replacing the five `plan_*` constructors: every planning knob
/// in one place, with the defaults the CLI uses (MWVC joint covers on the
/// hierarchical two-stage schedule, equal-row partitioning).
///
/// ```ignore
/// let dist = PlanSpec::new(Topology::tsubame4(8))
///     .strategy(Strategy::Adaptive)
///     .partitioner(Partitioner::NnzBalanced)
///     .n_dense(64)
///     .plan(&a);
/// ```
#[derive(Clone, Debug)]
pub struct PlanSpec {
    pub strategy: Strategy,
    pub topo: Topology,
    pub hierarchical: bool,
    pub params: PlanParams,
    pub partitioner: Partitioner,
    pub replicate: Replicate,
}

impl PlanSpec {
    pub fn new(topo: Topology) -> PlanSpec {
        PlanSpec {
            strategy: Strategy::Joint(Solver::Koenig),
            topo,
            hierarchical: true,
            params: PlanParams::default(),
            partitioner: Partitioner::Balanced,
            replicate: Replicate::default(),
        }
    }

    /// Communication strategy ([`Strategy::Adaptive`] routes through the
    /// per-pair plan compiler with this spec's params).
    pub fn strategy(mut self, strategy: Strategy) -> PlanSpec {
        self.strategy = strategy;
        self
    }

    /// Enable/disable the §6 two-stage hierarchical schedule.
    pub fn hierarchical(mut self, hierarchical: bool) -> PlanSpec {
        self.hierarchical = hierarchical;
        self
    }

    /// Flat (non-hierarchical) routing; shorthand for
    /// `.hierarchical(false)`.
    pub fn flat(self) -> PlanSpec {
        self.hierarchical(false)
    }

    /// Planner knobs (adaptive planning width, thread cap).
    pub fn params(mut self, params: PlanParams) -> PlanSpec {
        self.params = params;
        self
    }

    /// Planning dense width (`params.n_dense`): callers that execute at a
    /// non-default N should set it so the adaptive cost trade-off matches
    /// the actual run.
    pub fn n_dense(mut self, n: usize) -> PlanSpec {
        self.params.n_dense = n;
        self
    }

    /// Row-boundary choice: which nonzeros are remote.
    pub fn partitioner(mut self, partitioner: Partitioner) -> PlanSpec {
        self.partitioner = partitioner;
        self
    }

    /// 1.5D replication factor ([`Replicate::Factor`] must divide the
    /// rank count; [`Replicate::Auto`] picks by modeled cost). The group
    /// boundaries are the partitioner's rank boundaries coarsened, never
    /// a fresh split — the nesting is what guarantees inter-group volume
    /// is non-increasing in `c`.
    pub fn replicate(mut self, replicate: Replicate) -> PlanSpec {
        self.replicate = replicate;
        self
    }

    /// Plan a distributed SpMM of `a` over `topo.nranks` ranks:
    /// partitioner chooses the row boundaries, strategy plans how remote
    /// nonzeros are served, and `prep_secs` records the whole one-time
    /// preprocessing cost.
    pub fn plan(&self, a: &Csr) -> super::DistSpmm {
        self.build(a, None)
    }

    /// [`PlanSpec::plan`] consulting a [`PlanCache`] first, so repeated
    /// layers / epochs / tenants with the same sparsity pattern skip
    /// re-planning. Only [`Strategy::Adaptive`] plans are cached (the
    /// cache keys the per-pair compiler's inputs); other strategies plan
    /// directly.
    pub fn plan_cached(&self, a: &Csr, cache: &mut PlanCache) -> super::DistSpmm {
        self.build(a, Some(cache))
    }

    fn build(&self, a: &Csr, cache: Option<&mut PlanCache>) -> super::DistSpmm {
        use crate::partition::split_1d;
        let t0 = std::time::Instant::now();
        let part = self.partitioner.partition(a, self.topo.nranks, &self.topo, self.params.n_dense);
        let c = match self.replicate {
            Replicate::Factor(c) => c,
            Replicate::Auto => {
                crate::plan::choose_replication(a, &part, self.strategy, &self.topo, &self.params)
            }
        };
        assert!(
            c > 0 && self.topo.nranks % c == 0,
            "replication factor {c} must divide the rank count {}",
            self.topo.nranks
        );
        if c > 1 {
            // 1.5D path: plan at group granularity on the coarsened
            // topology. The group boundaries are the rank boundaries
            // coarsened, so per-pair covers nest inside the c=1 covers.
            let gpart = part.coarsen(c);
            let gblocks = split_1d(a, &gpart);
            let gtopo = self.topo.coarsen(c);
            let mut gparams = self.params.clone();
            gparams.replicate = c;
            let gplan = match (self.strategy, cache) {
                (Strategy::Adaptive, Some(cache)) => {
                    cache.get_or_compile(&gblocks, &gpart, &gtopo, &gparams).0
                }
                (Strategy::Adaptive, None) => {
                    crate::plan::compile(&gblocks, &gpart, &gtopo, &gparams).plan
                }
                (s, _) => crate::comm::plan(&gblocks, &gpart, s, None),
            };
            let map = crate::topology::ReplicaMap::new(self.topo.nranks, c);
            let rep = crate::hierarchy::build_replicated(&gplan, &map);
            let prep_secs = t0.elapsed().as_secs_f64();
            // No two-stage hierarchy: the replicated executor owns its
            // allgather/reduce-scatter wiring (DESIGN.md §13).
            return super::DistSpmm {
                part: gpart,
                blocks: gblocks,
                plan: gplan,
                sched: None,
                rep: Some(rep),
                topo: self.topo.clone(),
                prep_secs,
            };
        }
        let blocks = split_1d(a, &part);
        let plan = match (self.strategy, cache) {
            (Strategy::Adaptive, Some(cache)) => {
                cache.get_or_compile(&blocks, &part, &self.topo, &self.params).0
            }
            (Strategy::Adaptive, None) => {
                crate::plan::compile(&blocks, &part, &self.topo, &self.params).plan
            }
            (s, _) => crate::comm::plan(&blocks, &part, s, None),
        };
        let sched = self.hierarchical.then(|| crate::hierarchy::build(&plan, &self.topo));
        let prep_secs = t0.elapsed().as_secs_f64();
        super::DistSpmm { part, blocks, plan, sched, rep: None, topo: self.topo.clone(), prep_secs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::spmm::{serial_reference, DistSpmm};
    use crate::util::rng::Rng;

    #[test]
    fn plan_spec_defaults_match_legacy_plan() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 77);
        let d = PlanSpec::new(Topology::tsubame4(8)).plan(&a);
        #[allow(deprecated)]
        let legacy = DistSpmm::plan(
            &a,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(8),
            true,
        );
        assert_eq!(d.part.starts, legacy.part.starts);
        assert_eq!(d.plan.total_volume(32), legacy.plan.total_volume(32));
        assert_eq!(d.sched.is_some(), legacy.sched.is_some());
    }

    #[test]
    fn exec_request_builders_set_the_op() {
        let b = Dense::zeros(4, 2);
        let x = Dense::zeros(4, 2);
        assert_eq!(ExecRequest::spmm(&b).op, KernelOp::Spmm);
        assert!(ExecRequest::spmm(&b).x.is_none());
        let r = ExecRequest::sddmm(&x, &b);
        assert_eq!(r.op, KernelOp::Sddmm);
        assert!(r.x.is_some());
        let r = ExecRequest::fused(&x, &b).opts(ExecOpts::sequential()).backend(Backend::proc());
        assert_eq!(r.op, KernelOp::FusedSddmmSpmm);
        assert!(!r.opts.overlap);
        assert_eq!(r.backend.name(), "proc");
    }

    #[test]
    fn execute_request_roundtrip_all_kernels() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 78);
        let d = PlanSpec::new(Topology::tsubame4(8)).plan(&a);
        let mut rng = Rng::new(3);
        let b = Dense::random(128, 8, &mut rng);
        let x = Dense::random(128, 8, &mut rng);
        let (c, stats) = d.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        assert!(serial_reference(&a, &b).diff_norm(&c) < 1e-3);
        assert!(stats.wall_secs > 0.0);
        let (e, _) = d.execute(&ExecRequest::sddmm(&x, &b)).unwrap().into_sparse();
        assert_eq!(e, a.sddmm(&x, &b));
        let (cf, _) = d.execute(&ExecRequest::fused(&x, &b)).unwrap().into_dense();
        let want = a.sddmm(&x, &b).spmm(&b);
        assert!(want.diff_norm(&cf) / (want.max_abs() as f64 + 1e-30) < 1e-3);
    }

    #[test]
    fn handbuilt_request_without_x_is_a_structured_error() {
        let a = gen::rmat(64, 400, (0.55, 0.2, 0.19), false, 79);
        let d = PlanSpec::new(Topology::tsubame4(4)).plan(&a);
        let b = Dense::zeros(64, 4);
        let req = ExecRequest { op: KernelOp::Sddmm, ..ExecRequest::spmm(&b) };
        match d.execute(&req) {
            Err(ExecError::Unsupported(m)) => assert!(m.contains("X operand"), "{m}"),
            other => panic!("expected Unsupported, got {other:?}"),
        }
    }
}
