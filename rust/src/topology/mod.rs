//! Two-tier cluster topology model (paper §3.2, §7.1.2): groups of ranks
//! joined by fast intra-group links (NVLink / Xe Link) and slower
//! inter-group links (InfiniBand / Slingshot).

/// Link tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Intra,
    Inter,
}

/// A two-tier hierarchical topology. All bandwidths are bytes/second per
/// rank (NIC share), latencies in seconds.
#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub nranks: usize,
    /// Ranks per group (node). nranks need not be a multiple; the last
    /// group may be smaller.
    pub group_size: usize,
    pub intra_bw: f64,
    pub inter_bw: f64,
    pub intra_lat: f64,
    pub inter_lat: f64,
    /// Effective per-rank SpMM compute throughput (flops/s) — calibrated so
    /// the comm/compute *ratio* matches the paper's strong-scaling regime.
    pub compute_rate: f64,
    /// Per-kernel launch floor (s), models launch latency + cuSPARSE setup.
    pub kernel_launch: f64,
}

impl Topology {
    /// TSUBAME4.0 (paper §7.1.2): 4× H100 per node, NVLink 4.0 450 GB/s,
    /// InfiniBand NDR200 25 GB/s per node ⇒ ~6.25 GB/s per GPU (the paper's
    /// §7.7 quotes ~6 GB/s per GPU).
    pub fn tsubame4(nranks: usize) -> Topology {
        Topology {
            name: "tsubame4".into(),
            nranks,
            group_size: 4,
            intra_bw: 450e9,
            inter_bw: 6.25e9,
            intra_lat: 3e-6,
            inter_lat: 3e-6,
            compute_rate: 2.0e12, // effective sparse flops/s on H100
            kernel_launch: 20e-6,
        }
    }

    /// Aurora (paper §7.7): 12 PVC tiles per node via Xe Link at 15 GB/s,
    /// Slingshot-11 at 200 GB/s per node ⇒ ~17 GB/s per tile. The shallow
    /// bandwidth cliff (15 vs 17) makes hierarchy-aware scheduling
    /// unprofitable — Fig. 12's finding.
    pub fn aurora(nranks: usize) -> Topology {
        Topology {
            name: "aurora".into(),
            nranks,
            group_size: 12,
            intra_bw: 15e9,
            inter_bw: 17e9,
            intra_lat: 3e-6,
            inter_lat: 8e-6,
            compute_rate: 1.2e12,
            kernel_launch: 25e-6,
        }
    }

    /// Flat network: a single tier (group_size = nranks); used for unit
    /// tests and as the "no hierarchy" ablation control.
    pub fn flat(nranks: usize, bw: f64) -> Topology {
        Topology {
            name: "flat".into(),
            nranks,
            group_size: nranks.max(1),
            intra_bw: bw,
            inter_bw: bw,
            intra_lat: 5e-6,
            inter_lat: 5e-6,
            compute_rate: 2.0e12,
            kernel_launch: 20e-6,
        }
    }

    pub fn by_name(name: &str, nranks: usize) -> Option<Topology> {
        match name {
            "tsubame4" => Some(Topology::tsubame4(nranks)),
            "aurora" => Some(Topology::aurora(nranks)),
            "flat" => Some(Topology::flat(nranks, 25e9)),
            _ => None,
        }
    }

    #[inline]
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.group_size
    }

    pub fn ngroups(&self) -> usize {
        self.nranks.div_ceil(self.group_size)
    }

    /// Ranks in group g.
    pub fn group_members(&self, g: usize) -> std::ops::Range<usize> {
        let lo = g * self.group_size;
        lo..((g + 1) * self.group_size).min(self.nranks)
    }

    /// Vector of each rank's group id (for metrics).
    pub fn group_vec(&self) -> Vec<usize> {
        (0..self.nranks).map(|r| self.group_of(r)).collect()
    }

    #[inline]
    pub fn tier(&self, a: usize, b: usize) -> Tier {
        if self.group_of(a) == self.group_of(b) {
            Tier::Intra
        } else {
            Tier::Inter
        }
    }

    pub fn bw(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Intra => self.intra_bw,
            Tier::Inter => self.inter_bw,
        }
    }

    pub fn lat(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Intra => self.intra_lat,
            Tier::Inter => self.inter_lat,
        }
    }

    /// Bandwidth cliff ratio intra/inter — the hierarchy-aware strategy
    /// pays off when this is large (paper: TSUBAME 72×, Aurora ~0.9×).
    pub fn bandwidth_cliff(&self) -> f64 {
        self.intra_bw / self.inter_bw
    }

    /// Representative rank in destination group `g` for traffic sourced at
    /// rank `src`: spread by source to balance NIC load across the group.
    pub fn representative(&self, g: usize, src: usize) -> usize {
        let members = self.group_members(g);
        let len = members.len();
        members.start + src % len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tsubame_groups() {
        let t = Topology::tsubame4(32);
        assert_eq!(t.ngroups(), 8);
        assert_eq!(t.group_of(0), 0);
        assert_eq!(t.group_of(7), 1);
        assert_eq!(t.group_members(1), 4..8);
        assert_eq!(t.tier(0, 3), Tier::Intra);
        assert_eq!(t.tier(0, 4), Tier::Inter);
        assert!(t.bandwidth_cliff() > 10.0);
    }

    #[test]
    fn aurora_shallow_cliff() {
        let t = Topology::aurora(24);
        assert_eq!(t.ngroups(), 2);
        assert!(t.bandwidth_cliff() < 1.5);
    }

    #[test]
    fn flat_single_group() {
        let t = Topology::flat(16, 25e9);
        assert_eq!(t.ngroups(), 1);
        assert_eq!(t.tier(0, 15), Tier::Intra);
    }

    #[test]
    fn ragged_last_group() {
        let t = Topology::tsubame4(10);
        assert_eq!(t.ngroups(), 3);
        assert_eq!(t.group_members(2), 8..10);
        let rep = t.representative(2, 5);
        assert!(t.group_members(2).contains(&rep));
    }

    #[test]
    fn representative_balances() {
        let t = Topology::tsubame4(8);
        let reps: std::collections::HashSet<usize> =
            (0..4).map(|src| t.representative(1, src)).collect();
        assert_eq!(reps.len(), 4, "all members should serve as reps");
    }

    #[test]
    fn by_name_lookup() {
        assert!(Topology::by_name("tsubame4", 8).is_some());
        assert!(Topology::by_name("aurora", 24).is_some());
        assert!(Topology::by_name("unknown", 8).is_none());
    }
}
