//! Two-tier cluster topology model (paper §3.2, §7.1.2): groups of ranks
//! joined by fast intra-group links (NVLink / Xe Link) and slower
//! inter-group links (InfiniBand / Slingshot).

/// Link tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Intra,
    Inter,
}

/// A two-tier hierarchical topology. All bandwidths are bytes/second per
/// rank (NIC share), latencies in seconds.
#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub nranks: usize,
    /// Ranks per group (node). nranks need not be a multiple; the last
    /// group may be smaller.
    pub group_size: usize,
    pub intra_bw: f64,
    pub inter_bw: f64,
    pub intra_lat: f64,
    pub inter_lat: f64,
    /// Effective per-rank SpMM compute throughput (flops/s) — calibrated so
    /// the comm/compute *ratio* matches the paper's strong-scaling regime.
    pub compute_rate: f64,
    /// Per-kernel launch floor (s), models launch latency + cuSPARSE setup.
    pub kernel_launch: f64,
}

impl Topology {
    /// TSUBAME4.0 (paper §7.1.2): 4× H100 per node, NVLink 4.0 450 GB/s,
    /// InfiniBand NDR200 25 GB/s per node ⇒ ~6.25 GB/s per GPU (the paper's
    /// §7.7 quotes ~6 GB/s per GPU).
    pub fn tsubame4(nranks: usize) -> Topology {
        Topology {
            name: "tsubame4".into(),
            nranks,
            group_size: 4,
            intra_bw: 450e9,
            inter_bw: 6.25e9,
            intra_lat: 3e-6,
            inter_lat: 3e-6,
            compute_rate: 2.0e12, // effective sparse flops/s on H100
            kernel_launch: 20e-6,
        }
    }

    /// Aurora (paper §7.7): 12 PVC tiles per node via Xe Link at 15 GB/s,
    /// Slingshot-11 at 200 GB/s per node ⇒ ~17 GB/s per tile. The shallow
    /// bandwidth cliff (15 vs 17) makes hierarchy-aware scheduling
    /// unprofitable — Fig. 12's finding.
    pub fn aurora(nranks: usize) -> Topology {
        Topology {
            name: "aurora".into(),
            nranks,
            group_size: 12,
            intra_bw: 15e9,
            inter_bw: 17e9,
            intra_lat: 3e-6,
            inter_lat: 8e-6,
            compute_rate: 1.2e12,
            kernel_launch: 25e-6,
        }
    }

    /// Flat network: a single tier (group_size = nranks); used for unit
    /// tests and as the "no hierarchy" ablation control.
    pub fn flat(nranks: usize, bw: f64) -> Topology {
        Topology {
            name: "flat".into(),
            nranks,
            group_size: nranks.max(1),
            intra_bw: bw,
            inter_bw: bw,
            intra_lat: 5e-6,
            inter_lat: 5e-6,
            compute_rate: 2.0e12,
            kernel_launch: 20e-6,
        }
    }

    pub fn by_name(name: &str, nranks: usize) -> Option<Topology> {
        match name {
            "tsubame4" => Some(Topology::tsubame4(nranks)),
            "aurora" => Some(Topology::aurora(nranks)),
            "flat" => Some(Topology::flat(nranks, 25e9)),
            _ => None,
        }
    }

    #[inline]
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.group_size
    }

    pub fn ngroups(&self) -> usize {
        self.nranks.div_ceil(self.group_size)
    }

    /// Ranks in group g.
    pub fn group_members(&self, g: usize) -> std::ops::Range<usize> {
        let lo = g * self.group_size;
        lo..((g + 1) * self.group_size).min(self.nranks)
    }

    /// Vector of each rank's group id (for metrics).
    pub fn group_vec(&self) -> Vec<usize> {
        (0..self.nranks).map(|r| self.group_of(r)).collect()
    }

    #[inline]
    pub fn tier(&self, a: usize, b: usize) -> Tier {
        if self.group_of(a) == self.group_of(b) {
            Tier::Intra
        } else {
            Tier::Inter
        }
    }

    pub fn bw(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Intra => self.intra_bw,
            Tier::Inter => self.inter_bw,
        }
    }

    pub fn lat(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Intra => self.intra_lat,
            Tier::Inter => self.inter_lat,
        }
    }

    /// Bandwidth cliff ratio intra/inter — the hierarchy-aware strategy
    /// pays off when this is large (paper: TSUBAME 72×, Aurora ~0.9×).
    pub fn bandwidth_cliff(&self) -> f64 {
        self.intra_bw / self.inter_bw
    }

    /// Representative rank in destination group `g` for traffic sourced at
    /// rank `src`: spread by source to balance NIC load across the group.
    pub fn representative(&self, g: usize, src: usize) -> usize {
        let members = self.group_members(g);
        let len = members.len();
        members.start + src % len
    }

    /// Group-level view of this topology under replication factor `c`
    /// (`c` must divide `nranks`): one logical rank per replication group,
    /// with the physical `group_size` shrunk by the same factor so the
    /// cost model keeps pricing a flow between two replication groups at
    /// the tier their home ranks actually use. Replication groups are
    /// `c` *consecutive* ranks, so when `c` divides `group_size` they
    /// nest inside nodes and a coarsened group pair is Inter exactly when
    /// the underlying home pair is.
    pub fn coarsen(&self, c: usize) -> Topology {
        assert!(c > 0, "replication factor must be positive");
        assert_eq!(
            self.nranks % c,
            0,
            "replication factor {c} must divide nranks {}",
            self.nranks
        );
        Topology {
            name: self.name.clone(),
            nranks: self.nranks / c,
            group_size: (self.group_size / c).max(1),
            ..self.clone()
        }
    }
}

/// Rank ↔ replication-group addressing for the 1.5D decomposition
/// (ROADMAP item 3, SpComm3D's replication axis): `nranks` physical ranks
/// are grouped into `nranks/c` groups of `c` *consecutive* ranks. Rank
/// `g·c` is the group's **home** — it owns the group's A rows and B/C row
/// ranges — and the other `c-1` members hold replicas of the group's A
/// block and serve a share of the group's inter-group flows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaMap {
    pub nranks: usize,
    /// Replication factor (`c ≥ 1`, divides `nranks`).
    pub c: usize,
}

impl ReplicaMap {
    pub fn new(nranks: usize, c: usize) -> ReplicaMap {
        assert!(c > 0, "replication factor must be positive");
        assert!(nranks > 0, "need at least one rank");
        assert_eq!(nranks % c, 0, "replication factor {c} must divide nranks {nranks}");
        ReplicaMap { nranks, c }
    }

    #[inline]
    pub fn ngroups(&self) -> usize {
        self.nranks / self.c
    }

    /// Replication group of rank `r`.
    #[inline]
    pub fn group_of(&self, r: usize) -> usize {
        r / self.c
    }

    /// Member index of rank `r` inside its group (0 = home).
    #[inline]
    pub fn member_of(&self, r: usize) -> usize {
        r % self.c
    }

    /// The home rank of group `g`.
    #[inline]
    pub fn home(&self, g: usize) -> usize {
        g * self.c
    }

    /// Physical rank of member `t` of group `g`.
    #[inline]
    pub fn rank(&self, g: usize, t: usize) -> usize {
        debug_assert!(t < self.c);
        g * self.c + t
    }

    /// Ranks in group `g`.
    pub fn members(&self, g: usize) -> std::ops::Range<usize> {
        g * self.c..(g + 1) * self.c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tsubame_groups() {
        let t = Topology::tsubame4(32);
        assert_eq!(t.ngroups(), 8);
        assert_eq!(t.group_of(0), 0);
        assert_eq!(t.group_of(7), 1);
        assert_eq!(t.group_members(1), 4..8);
        assert_eq!(t.tier(0, 3), Tier::Intra);
        assert_eq!(t.tier(0, 4), Tier::Inter);
        assert!(t.bandwidth_cliff() > 10.0);
    }

    #[test]
    fn aurora_shallow_cliff() {
        let t = Topology::aurora(24);
        assert_eq!(t.ngroups(), 2);
        assert!(t.bandwidth_cliff() < 1.5);
    }

    #[test]
    fn flat_single_group() {
        let t = Topology::flat(16, 25e9);
        assert_eq!(t.ngroups(), 1);
        assert_eq!(t.tier(0, 15), Tier::Intra);
    }

    #[test]
    fn ragged_last_group() {
        let t = Topology::tsubame4(10);
        assert_eq!(t.ngroups(), 3);
        assert_eq!(t.group_members(2), 8..10);
        let rep = t.representative(2, 5);
        assert!(t.group_members(2).contains(&rep));
    }

    #[test]
    fn representative_balances() {
        let t = Topology::tsubame4(8);
        let reps: std::collections::HashSet<usize> =
            (0..4).map(|src| t.representative(1, src)).collect();
        assert_eq!(reps.len(), 4, "all members should serve as reps");
    }

    #[test]
    fn replica_map_addressing() {
        let m = ReplicaMap::new(8, 2);
        assert_eq!(m.ngroups(), 4);
        assert_eq!(m.group_of(5), 2);
        assert_eq!(m.member_of(5), 1);
        assert_eq!(m.home(2), 4);
        assert_eq!(m.rank(3, 1), 7);
        assert_eq!(m.members(1), 2..4);
        for r in 0..8 {
            assert_eq!(m.rank(m.group_of(r), m.member_of(r)), r);
        }
        let id = ReplicaMap::new(4, 1);
        assert_eq!(id.ngroups(), 4);
        for r in 0..4 {
            assert_eq!(id.home(r), r);
            assert_eq!(id.member_of(r), 0);
        }
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn replica_map_rejects_nondivisor() {
        let _ = ReplicaMap::new(6, 4);
    }

    #[test]
    fn coarsened_topology_preserves_tiering() {
        // c=2 on tsubame4 (group_size 4): replication groups nest inside
        // nodes, so two coarse ranks are Inter exactly when their home
        // ranks live on different nodes.
        let t = Topology::tsubame4(16);
        let ct = t.coarsen(2);
        assert_eq!(ct.nranks, 8);
        assert_eq!(ct.group_size, 2);
        let m = ReplicaMap::new(16, 2);
        for ga in 0..8 {
            for gb in 0..8 {
                assert_eq!(
                    ct.tier(ga, gb),
                    t.tier(m.home(ga), m.home(gb)),
                    "coarse pair ({ga},{gb})"
                );
            }
        }
        // c larger than group_size degrades to one coarse rank per node
        // bucket (group_size floor of 1) without panicking.
        let big = t.coarsen(8);
        assert_eq!(big.nranks, 2);
        assert_eq!(big.group_size, 1);
    }

    #[test]
    fn by_name_lookup() {
        assert!(Topology::by_name("tsubame4", 8).is_some());
        assert!(Topology::by_name("aurora", 24).is_some());
        assert!(Topology::by_name("unknown", 8).is_none());
    }
}
