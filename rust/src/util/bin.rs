//! Little-endian binary IO primitives shared by the on-disk plan cache
//! ([`crate::plan::cache`]) and the multiproc wire format
//! (`exec::wire`). Both serialize the same objects — CSR sub-blocks,
//! dense payloads, length-prefixed index lists — so the encoding lives
//! in one place: every multi-byte integer is little-endian, floats
//! travel as raw IEEE-754 bits (`to_bits`/`from_bits`, so values
//! roundtrip bitwise including NaN payloads), and every variable-length
//! read is bounded by a caller-provided element budget so truncated or
//! corrupt input fails with a clean error instead of attempting a huge
//! allocation.

use crate::dense::Dense;
use crate::sparse::Csr;
use anyhow::{bail, Result};
use std::io::{Read, Write};

// ------------------------------------------------------------- scalars ----

pub fn w_u8<W: Write>(w: &mut W, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

pub fn r_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn w_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn r_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn w_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn r_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn w_f32<W: Write>(w: &mut W, v: f32) -> Result<()> {
    w.write_all(&v.to_bits().to_le_bytes())?;
    Ok(())
}

pub fn r_f32<R: Read>(r: &mut R) -> Result<f32> {
    Ok(f32::from_bits(r_u32(r)?))
}

pub fn w_f64<W: Write>(w: &mut W, v: f64) -> Result<()> {
    w.write_all(&v.to_bits().to_le_bytes())?;
    Ok(())
}

pub fn r_f64<R: Read>(r: &mut R) -> Result<f64> {
    Ok(f64::from_bits(r_u64(r)?))
}

// ------------------------------------------- length-prefixed sequences ----

/// Bounds check shared by every length-prefixed read: `len` elements were
/// claimed, `max_elems` can actually exist (each element occupies ≥ 4
/// bytes in every on-disk / on-wire encoding, so callers derive the bound
/// from `bytes / 4`).
fn check_len(len: u64, max_elems: usize, what: &str) -> Result<usize> {
    if len > max_elems as u64 {
        bail!("corrupt input: {what} length {len} exceeds available bytes");
    }
    Ok(len as usize)
}

pub fn w_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    w_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub fn r_str<R: Read>(r: &mut R, max_bytes: usize) -> Result<String> {
    let len = r_u64(r)?;
    if len > max_bytes as u64 {
        bail!("corrupt input: string length {len} exceeds available bytes");
    }
    let mut b = vec![0u8; len as usize];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

pub fn w_u32s<W: Write>(w: &mut W, xs: &[u32]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for &x in xs {
        w_u32(w, x)?;
    }
    Ok(())
}

pub fn r_u32s<R: Read>(r: &mut R, max_elems: usize) -> Result<Vec<u32>> {
    let len = check_len(r_u64(r)?, max_elems, "u32 list")?;
    let mut xs = vec![0u32; len];
    for x in xs.iter_mut() {
        *x = r_u32(r)?;
    }
    Ok(xs)
}

pub fn w_u64s<W: Write>(w: &mut W, xs: &[u64]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for &x in xs {
        w_u64(w, x)?;
    }
    Ok(())
}

pub fn r_u64s<R: Read>(r: &mut R, max_elems: usize) -> Result<Vec<u64>> {
    let len = check_len(r_u64(r)?, max_elems, "u64 list")?;
    let mut xs = vec![0u64; len];
    for x in xs.iter_mut() {
        *x = r_u64(r)?;
    }
    Ok(xs)
}

pub fn w_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for &x in xs {
        w_f32(w, x)?;
    }
    Ok(())
}

pub fn r_f32s<R: Read>(r: &mut R, max_elems: usize) -> Result<Vec<f32>> {
    let len = check_len(r_u64(r)?, max_elems, "f32 list")?;
    let mut xs = vec![0f32; len];
    for x in xs.iter_mut() {
        *x = r_f32(r)?;
    }
    Ok(xs)
}

// ------------------------------------------------------------ matrices ----

/// CSR encoding: `nrows | ncols | nnz | indptr[nrows+1] | indices[nnz] |
/// data[nnz]`. Kept byte-identical to the original plan-cache layout so
/// existing cache entries stay readable (PLAN_VERSION unchanged).
pub fn w_csr<W: Write>(w: &mut W, m: &Csr) -> Result<()> {
    w_u64(w, m.nrows as u64)?;
    w_u64(w, m.ncols as u64)?;
    w_u64(w, m.nnz() as u64)?;
    for &v in &m.indptr {
        w_u64(w, v)?;
    }
    for &c in &m.indices {
        w_u32(w, c)?;
    }
    for &v in &m.data {
        w_f32(w, v)?;
    }
    Ok(())
}

/// `max_elems` bounds every length field against the input's actual size
/// (each element occupies ≥ 4 bytes), so a truncated or corrupt stream
/// fails with a clean error instead of attempting a huge allocation. The
/// decoded matrix is structurally validated before being returned.
pub fn r_csr<R: Read>(r: &mut R, max_elems: usize) -> Result<Csr> {
    let nrows = r_u64(r)? as usize;
    let ncols = r_u64(r)? as usize;
    let nnz = r_u64(r)? as usize;
    if nrows > max_elems || nnz > max_elems {
        bail!("corrupt input: csr dims {nrows}x{ncols} nnz {nnz} exceed available bytes");
    }
    let mut indptr = vec![0u64; nrows + 1];
    for v in indptr.iter_mut() {
        *v = r_u64(r)?;
    }
    let mut indices = vec![0u32; nnz];
    for v in indices.iter_mut() {
        *v = r_u32(r)?;
    }
    let mut data = vec![0f32; nnz];
    for v in data.iter_mut() {
        *v = r_f32(r)?;
    }
    let m = Csr { nrows, ncols, indptr, indices, data };
    m.validate()?;
    Ok(m)
}

/// Dense encoding: `nrows | ncols | data[nrows*ncols]` (no separate
/// length word — the shape is the length).
pub fn w_dense<W: Write>(w: &mut W, d: &Dense) -> Result<()> {
    w_u64(w, d.nrows as u64)?;
    w_u64(w, d.ncols as u64)?;
    for &v in &d.data {
        w_f32(w, v)?;
    }
    Ok(())
}

pub fn r_dense<R: Read>(r: &mut R, max_elems: usize) -> Result<Dense> {
    let nrows = r_u64(r)? as usize;
    let ncols = r_u64(r)? as usize;
    let elems = nrows
        .checked_mul(ncols)
        .ok_or_else(|| anyhow::anyhow!("corrupt input: dense shape {nrows}x{ncols} overflows"))?;
    if elems > max_elems {
        bail!("corrupt input: dense shape {nrows}x{ncols} exceeds available bytes");
    }
    let mut data = vec![0f32; elems];
    for v in data.iter_mut() {
        *v = r_f32(r)?;
    }
    Ok(Dense { nrows, ncols, data })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    #[test]
    fn scalar_roundtrips() {
        let mut buf = Vec::new();
        w_u8(&mut buf, 7).unwrap();
        w_u32(&mut buf, 0xdead_beef).unwrap();
        w_u64(&mut buf, u64::MAX - 1).unwrap();
        w_f32(&mut buf, -0.0).unwrap();
        w_f64(&mut buf, f64::NAN).unwrap();
        let mut r = &buf[..];
        assert_eq!(r_u8(&mut r).unwrap(), 7);
        assert_eq!(r_u32(&mut r).unwrap(), 0xdead_beef);
        assert_eq!(r_u64(&mut r).unwrap(), u64::MAX - 1);
        // Bitwise float transport: -0.0 and NaN survive exactly.
        assert_eq!(r_f32(&mut r).unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r_f64(&mut r).unwrap().is_nan());
        assert!(r.is_empty());
    }

    #[test]
    fn sequence_roundtrips() {
        let mut buf = Vec::new();
        w_str(&mut buf, "tsubame4").unwrap();
        w_u32s(&mut buf, &[3, 1, 4, 1, 5]).unwrap();
        w_u64s(&mut buf, &[0, u64::MAX]).unwrap();
        w_f32s(&mut buf, &[1.5, -2.25]).unwrap();
        let n = buf.len();
        let mut r = &buf[..];
        assert_eq!(r_str(&mut r, n).unwrap(), "tsubame4");
        assert_eq!(r_u32s(&mut r, n / 4).unwrap(), vec![3, 1, 4, 1, 5]);
        assert_eq!(r_u64s(&mut r, n / 4).unwrap(), vec![0, u64::MAX]);
        assert_eq!(r_f32s(&mut r, n / 4).unwrap(), vec![1.5, -2.25]);
    }

    #[test]
    fn oversized_length_rejected() {
        let mut buf = Vec::new();
        w_u64(&mut buf, 1 << 60).unwrap(); // absurd length claim
        let mut r = &buf[..];
        assert!(r_u32s(&mut r, buf.len() / 4).is_err());
        let mut r2 = &buf[..];
        assert!(r_str(&mut r2, buf.len()).is_err());
    }

    #[test]
    fn csr_and_dense_roundtrip() {
        let mut coo = Coo::new(4, 5);
        coo.push(0, 1, 1.5);
        coo.push(2, 4, -3.0);
        coo.push(3, 0, 0.25);
        let m = coo.to_csr();
        let d = Dense::from_fn(3, 4, |i, j| (i * 4 + j) as f32 - 5.5);
        let mut buf = Vec::new();
        w_csr(&mut buf, &m).unwrap();
        w_dense(&mut buf, &d).unwrap();
        let bound = buf.len() / 4;
        let mut r = &buf[..];
        assert_eq!(r_csr(&mut r, bound).unwrap(), m);
        assert_eq!(r_dense(&mut r, bound).unwrap(), d);
        assert!(r.is_empty());
        // Truncated input fails cleanly.
        let mut short = &buf[..buf.len() / 2];
        let res = r_csr(&mut short, bound).and_then(|_| r_dense(&mut short, bound));
        assert!(res.is_err());
    }
}
