//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §1).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--ranks", "32", "--dataset=mawi", "--verbose"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("ranks"), Some("32"));
        assert_eq!(a.get("dataset"), Some("mawi"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "64", "--alpha", "1.5"]);
        assert_eq!(a.get_usize("n", 0), 64);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert!((a.get_f64("alpha", 0.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--check"]);
        assert!(a.has_flag("check"));
        assert!(a.get("check").is_none());
    }

    #[test]
    fn flag_before_option() {
        let a = parse(&["--fast", "--n", "3"]);
        assert!(a.has_flag("fast") || a.get("fast") == Some("--n"));
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_usize("n", 0), 3);
    }
}
