//! Small self-contained utilities: deterministic RNG, timers, a
//! quickcheck-style property-testing harness, and a mini-TOML parser.
//!
//! These replace crates (rand, criterion, proptest, serde/toml) that are not
//! available in the offline build image — see DESIGN.md §1.

pub mod bin;
pub mod cli;
pub mod proptest;
pub mod rng;
pub mod timer;
pub mod toml_mini;

/// Geometric mean of a slice of positive values; returns 0.0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Human-readable byte count (binary prefixes).
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0} {}", v, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Human-readable duration given seconds.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[7.0]) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KiB");
        assert_eq!(human_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(2.0), "2.000 s");
        assert_eq!(human_secs(0.5e-3), "500.00 µs");
        assert_eq!(human_secs(0.25), "250.00 ms");
    }
}
