//! Minimal quickcheck-style property-testing harness (proptest is not
//! available in the offline image — see DESIGN.md §1).
//!
//! Usage (`no_run`: doctest binaries don't get the xla rpath):
//! ```no_run
//! use shiro::util::proptest::{forall, Gen};
//! forall("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! On failure the harness re-runs the failing case with its seed printed so
//! it can be reproduced exactly.

use crate::util::rng::Rng;

/// Value generator handed to each property-test case.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            case_seed: seed,
        }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        if lo >= hi {
            return lo;
        }
        self.rng.range(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Size parameter biased toward small values (exercises edge cases more).
    pub fn small_size(&mut self, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        // ~50% of draws land below max/8.
        if self.rng.chance(0.5) {
            self.rng.below(max / 8 + 1)
        } else {
            self.rng.below(max + 1)
        }
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.f32() * 2.0 - 1.0).collect()
    }
}

/// Run `cases` randomized cases of `prop`. Panics (with the failing seed)
/// if any case panics.
pub fn forall(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // Base seed derived from the property name so distinct properties explore
    // distinct streams but remain fully deterministic.
    let base: u64 = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall("add-commutes", 50, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        forall("always-fails", 10, |g| {
            let x = g.usize_in(0, 10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        use std::sync::Mutex;
        let first = Mutex::new(Vec::new());
        forall("det", 5, |g| {
            first.lock().unwrap().push(g.usize_in(0, 1_000_000));
        });
        let second = Mutex::new(Vec::new());
        forall("det", 5, |g| {
            second.lock().unwrap().push(g.usize_in(0, 1_000_000));
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }

    #[test]
    fn small_size_in_bounds() {
        forall("small-size", 100, |g| {
            let s = g.small_size(64);
            assert!(s <= 64);
        });
    }
}
