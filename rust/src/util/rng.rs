//! Deterministic PRNG (splitmix64 seeding + xoshiro256**), no external deps.
//!
//! All experiments in this repo are seeded so every figure/table is exactly
//! reproducible run-to-run.

/// xoshiro256** generator. Deterministic, fast, good statistical quality for
/// workload generation (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample from an (unnormalized) discrete weight distribution.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-ish power-law sample over [0, n): P(k) ∝ (k+1)^-alpha.
    /// Uses inverse-CDF approximation adequate for workload skew modelling.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        debug_assert!(alpha > 0.0 && alpha != 1.0);
        let u = self.f64();
        // Inverse of the continuous CDF of x^-alpha on [1, n+1].
        let a = 1.0 - alpha;
        let x = ((n as f64 + 1.0).powf(a) * u + (1.0 - u)).powf(1.0 / a);
        ((x - 1.0) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn weighted_respects_zero() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn powerlaw_skews_low() {
        let mut r = Rng::new(13);
        let mut lo = 0;
        for _ in 0..10_000 {
            if r.powerlaw(1000, 1.5) < 10 {
                lo += 1;
            }
        }
        // Heavy head: far more than uniform's ~1%.
        assert!(lo > 2_000, "head mass {lo}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
