//! Wall-clock timing helpers and a tiny statistics type used by the bench
//! harness (replaces criterion, which is unavailable offline).

use std::time::Instant;

/// Run `f` once and return (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Summary statistics over repeated timed runs.
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `runs` timed runs.
/// Mirrors the paper's methodology (5 warmups + 100 timed; callers scale
/// counts down for CI-sized workloads).
pub fn benchmark<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Stats::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn stats_odd_median() {
        let s = Stats::from_samples(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn benchmark_runs_counted() {
        let mut count = 0usize;
        let s = benchmark(2, 5, || {
            count += 1;
        });
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn time_once_positive() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
