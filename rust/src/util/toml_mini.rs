//! Minimal TOML-subset parser for experiment configs (serde/toml crates are
//! unavailable offline — DESIGN.md §1).
//!
//! Supported: `[table]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous array values, `#` comments. Nested tables are
//! flattened as `table.key` lookups.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parsed config: keys are `section.key` (or bare `key` for the root table).
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub entries: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ParseError {
                        line: lineno,
                        msg: format!("malformed table header {line:?}"),
                    });
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let value = parse_value(v.trim()).map_err(|msg| ParseError { line: lineno, msg })?;
            entries.insert(key, value);
        }
        Ok(Config { entries })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Config::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_int()).unwrap_or(default)
    }
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings. A backslash escapes the next
    // character inside a string, so `\"` does not close it — this scanner
    // and the string lexer in `parse_string` must agree on that, or a
    // value like `"say \"hi\" # not a comment"` is truncated mid-string.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Lex a double-quoted string with backslash escapes (`\"`, `\\`, `\n`,
/// `\t`), requiring the closing quote to end the input.
fn parse_string(s: &str) -> Result<Value, String> {
    debug_assert!(s.starts_with('"'));
    let mut out = String::new();
    let mut chars = s[1..].chars();
    loop {
        match chars.next() {
            None => return Err(format!("unterminated string {s:?}")),
            Some('"') => break,
            Some('\\') => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some(c) => return Err(format!("unknown escape \\{c} in {s:?}")),
                None => return Err(format!("unterminated string {s:?}")),
            },
            Some(c) => out.push(c),
        }
    }
    if chars.next().is_some() {
        return Err(format!("trailing characters after string {s:?}"));
    }
    Ok(Value::Str(out))
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.starts_with('"') {
        return parse_string(s);
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(format!("unterminated array {s:?}"));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split a comma-separated list, respecting nested brackets and strings
/// (with the same `\"` escape convention as [`parse_string`]).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        let c = Config::parse(
            r#"
            name = "mawi"  # dataset
            rows = 1000
            density = 3.0e-8
            symmetric = true
            "#,
        )
        .unwrap();
        assert_eq!(c.str_or("name", ""), "mawi");
        assert_eq!(c.int_or("rows", 0), 1000);
        assert!((c.float_or("density", 0.0) - 3.0e-8).abs() < 1e-20);
        assert!(c.bool_or("symmetric", false));
    }

    #[test]
    fn parse_sections() {
        let c = Config::parse(
            "[topology]\ngroups = 8\n[run]\nranks = 32\n",
        )
        .unwrap();
        assert_eq!(c.int_or("topology.groups", 0), 8);
        assert_eq!(c.int_or("run.ranks", 0), 32);
    }

    #[test]
    fn parse_arrays() {
        let c = Config::parse("ns = [32, 64, 128]\nnames = [\"a\", \"b\"]\n").unwrap();
        let ns = c.get("ns").unwrap().as_array().unwrap();
        assert_eq!(ns.len(), 3);
        assert_eq!(ns[2].as_int(), Some(128));
        let names = c.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b"));
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("tag = \"a#b\"\n").unwrap();
        assert_eq!(c.str_or("tag", ""), "a#b");
    }

    #[test]
    fn escaped_quote_and_hash_inside_string() {
        // Satellite regression (PR 6): `\"` must not toggle the comment
        // stripper's string state, and `#` inside the string must survive.
        let c = Config::parse(
            "name = \"say \\\"hi\\\" # not a comment\"  # real comment\n",
        )
        .unwrap();
        assert_eq!(c.str_or("name", ""), "say \"hi\" # not a comment");
    }

    #[test]
    fn escape_sequences_unescaped() {
        let c = Config::parse("path = \"a\\\\b\"\ntab = \"x\\ty\"\nnl = \"p\\nq\"\n")
            .unwrap();
        assert_eq!(c.str_or("path", ""), "a\\b");
        assert_eq!(c.str_or("tab", ""), "x\ty");
        assert_eq!(c.str_or("nl", ""), "p\nq");
    }

    #[test]
    fn escaped_quotes_inside_arrays() {
        let c = Config::parse("xs = [\"a\\\"b\", \"c,d\"]\n").unwrap();
        let xs = c.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs[0].as_str(), Some("a\"b"));
        assert_eq!(xs[1].as_str(), Some("c,d"));
    }

    #[test]
    fn bad_strings_error() {
        // An escaped final quote leaves the string unterminated.
        assert!(Config::parse("s = \"oops\\\"\n").is_err());
        // Unknown escapes are rejected, not silently passed through.
        assert!(Config::parse("s = \"a\\qb\"\n").is_err());
        // Junk after the closing quote is rejected.
        assert!(Config::parse("s = \"ab\"cd\n").is_err());
    }

    #[test]
    fn error_reports_line() {
        let err = Config::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("xs = []\n").unwrap();
        assert_eq!(c.get("xs").unwrap().as_array().unwrap().len(), 0);
    }
}
