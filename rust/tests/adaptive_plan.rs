//! Tentpole test coverage: the adaptive per-pair plan compiler.
//!
//! - Strategy-equivalence: every `Strategy` × routing mode — including
//!   `Adaptive` — produces **bit-identical** C against the serial
//!   reference. Matrices and dense inputs are integer-valued and bounded
//!   well inside f32's exact range (|C| < 2^24), so every summation order
//!   yields the same bits and exact equality is a sound assertion.
//! - Cost guarantees: the adaptive picker's per-pair choice never costs
//!   more than any fixed shape, and the plan's modeled α-β total is ≤ the
//!   minimum across the four fixed strategies on every registry dataset.
//! - Cache: a cached adaptive plan is the plan that would have been
//!   compiled, and executes exactly.

use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::partition::{split_1d, RowPartition};
use shiro::plan::{self, PlanParams, Shape};
use shiro::sparse::{Coo, Csr, DATASETS};
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::proptest::{forall, Gen};

/// Random sparse matrix with small integer values (exact in f32).
fn int_matrix(g: &mut Gen, n: usize, nnz: usize) -> Csr {
    let mut coo = Coo::new(n, n);
    for _ in 0..nnz {
        let r = g.rng().below(n);
        let c = g.rng().below(n);
        let v = (1 + g.rng().below(4)) as f32;
        coo.push(r, c, v);
    }
    coo.to_csr()
}

/// Integer-valued dense input in [-4, 4].
fn int_dense(n: usize, nd: usize) -> Dense {
    Dense::from_fn(n, nd, |i, j| ((i * 7 + j * 13) % 9) as f32 - 4.0)
}

fn all_strategies() -> [Strategy; 7] {
    [
        Strategy::Block,
        Strategy::Column,
        Strategy::Row,
        Strategy::Joint(Solver::Koenig),
        Strategy::Joint(Solver::Dinic),
        Strategy::Joint(Solver::Greedy),
        Strategy::Adaptive,
    ]
}

#[test]
fn prop_all_strategies_bit_identical_to_serial() {
    forall("strategy-equivalence", 6, |g| {
        let n = 64 + 32 * g.usize_in(0, 5);
        let a = int_matrix(g, n, n * (2 + g.usize_in(0, 5)));
        let ranks = g.usize_in(2, 9);
        let nd = 1 + g.usize_in(0, 12);
        let b = int_dense(n, nd);
        let want = a.spmm(&b);
        for strategy in all_strategies() {
            for hier in [false, true] {
                if hier && strategy == Strategy::Block {
                    continue; // block mode is defined flat-only in the paper
                }
                let d = PlanSpec::new(Topology::tsubame4(ranks))
                    .strategy(strategy)
                    .hierarchical(hier)
                    .plan(&a);
                let (got, _) = d
                    .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
                    .expect("thread-backend SpMM")
                    .into_dense();
                assert_eq!(
                    got.data, want.data,
                    "{strategy:?} hier={hier} ranks={ranks} not bit-identical"
                );
            }
        }
    });
}

#[test]
fn prop_adaptive_pair_choice_never_costlier_than_fixed() {
    forall("adaptive-pair-optimal", 10, |g| {
        let n = 64 + 32 * g.usize_in(0, 5);
        let a = int_matrix(g, n, n * (2 + g.usize_in(0, 6)));
        let ranks = g.usize_in(2, 10);
        let part = RowPartition::balanced(n, ranks);
        let blocks = split_1d(&a, &part);
        let topo = if g.bool() {
            Topology::tsubame4(ranks)
        } else {
            Topology::aurora(ranks)
        };
        let params = PlanParams::default();
        let compiled = plan::compile(&blocks, &part, &topo, &params);
        for p in 0..ranks {
            for q in 0..ranks {
                if p == q || blocks[p].off_diag[q].nnz() == 0 {
                    continue;
                }
                let tier = topo.tier(p, q);
                let chosen = plan::pair_cost(
                    &compiled.plan.pairs[p][q],
                    part.len(q),
                    tier,
                    &topo,
                    params.n_dense,
                );
                for shape in Shape::ALL {
                    let cand = shiro::comm::plan_pair(
                        &blocks[p].off_diag[q],
                        shape.strategy(),
                        p,
                        q,
                        None,
                    );
                    let cost =
                        plan::pair_cost(&cand, part.len(q), tier, &topo, params.n_dense);
                    assert!(
                        chosen <= cost,
                        "({p},{q}) on {}: adaptive {chosen} > {} {cost}",
                        topo.name,
                        shape.name()
                    );
                }
            }
        }
    });
}

/// Acceptance criterion: on every generated registry matrix, the adaptive
/// plan's modeled α-β total is ≤ the minimum across the four fixed
/// strategies.
#[test]
fn adaptive_total_cost_le_best_fixed_on_all_datasets() {
    let ranks = 8;
    let topo = Topology::tsubame4(ranks);
    let params = PlanParams::default();
    for spec in DATASETS {
        let a = spec.generate(0.005);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let compiled = plan::compile(&blocks, &part, &topo, &params);
        let mut best_fixed = f64::INFINITY;
        for shape in Shape::ALL {
            let fixed = shiro::comm::plan(&blocks, &part, shape.strategy(), None);
            best_fixed = best_fixed.min(plan::modeled_cost(&fixed, &topo, params.n_dense));
        }
        assert!(
            compiled.modeled_cost <= best_fixed + 1e-12,
            "{}: adaptive {} > best fixed {}",
            spec.name,
            compiled.modeled_cost,
            best_fixed
        );
        // And the adaptive plan is never worse than joint (the per-pair
        // dominant shape) on plain volume either.
        let joint = shiro::comm::plan(
            &blocks,
            &part,
            Strategy::Joint(Solver::Koenig),
            None,
        );
        let adaptive_cost = plan::modeled_cost(&compiled.plan, &topo, params.n_dense);
        let joint_cost = plan::modeled_cost(&joint, &topo, params.n_dense);
        assert!(adaptive_cost <= joint_cost + 1e-12, "{}", spec.name);
    }
}

#[test]
fn adaptive_selectable_from_config() {
    use shiro::config::RunConfig;
    let cfg = RunConfig { strategy: "adaptive".into(), ..Default::default() };
    assert_eq!(cfg.strategy(), Strategy::Adaptive);
    // A config-selected adaptive strategy drives the engine end to end.
    let mut g = Gen::new(42);
    let a = int_matrix(&mut g, 96, 700);
    let d = PlanSpec::new(Topology::tsubame4(4)).strategy(cfg.strategy()).plan(&a);
    let b = int_dense(96, 8);
    let (got, _) = d
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    assert_eq!(got.data, a.spmm(&b).data);
}

#[test]
fn cached_plan_executes_bit_identically() {
    let mut g = Gen::new(7);
    let a = int_matrix(&mut g, 128, 1000);
    let topo = Topology::tsubame4(8);
    let mut cache = shiro::plan::cache::PlanCache::in_memory();
    let params = PlanParams::default();
    let spec = PlanSpec::new(topo.clone()).strategy(Strategy::Adaptive).params(params.clone());
    let d_cold = spec.plan_cached(&a, &mut cache);
    let d_warm = spec.plan_cached(&a, &mut cache);
    assert_eq!((cache.hits, cache.misses), (1, 1));
    let b = int_dense(128, 16);
    let want = a.spmm(&b);
    let (c1, _) = d_cold
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    let (c2, _) = d_warm
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    assert_eq!(c1.data, want.data);
    assert_eq!(c2.data, want.data);
}

#[test]
fn adaptive_beats_or_ties_fixed_strategies_in_simulated_time_shape() {
    // Not a makespan guarantee (list scheduling is not monotone), but the
    // compiler's own objective must dominate: check it on a skewed web
    // pattern across both evaluation topologies.
    let a = shiro::sparse::gen::powerlaw(512, 8000, 1.4, 3);
    for ranks in [8usize, 16] {
        for topo in [Topology::tsubame4(ranks), Topology::aurora(ranks)] {
            let part = RowPartition::balanced(a.nrows, ranks);
            let blocks = split_1d(&a, &part);
            let params = PlanParams::default();
            let compiled = plan::compile(&blocks, &part, &topo, &params);
            for shape in Shape::ALL {
                let fixed = shiro::comm::plan(&blocks, &part, shape.strategy(), None);
                assert!(
                    compiled.modeled_cost
                        <= plan::modeled_cost(&fixed, &topo, params.n_dense) + 1e-12
                );
            }
        }
    }
}
