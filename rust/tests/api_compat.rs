//! API-compat differential suite (the `ExecRequest` redesign's safety
//! net): every `#[deprecated]` legacy method must be **bitwise identical**
//! to its [`ExecRequest`]/[`PlanSpec`] replacement — same plans, same
//! executed bits, same measured traffic. Inputs are integer-exact so
//! bitwise equality is meaningful everywhere.
#![allow(deprecated)]

use std::time::Duration;

use shiro::bench::int_matrix;
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::exec::ExecOpts;
use shiro::partition::Partitioner;
use shiro::plan::cache::PlanCache;
use shiro::plan::PlanParams;
use shiro::runtime::multiproc::ProcOpts;
use shiro::spmm::{Backend, DistSddmm, DistSpmm, ExecRequest, PlanSpec};
use shiro::topology::Topology;

fn fixtures() -> (shiro::sparse::Csr, Dense, Dense, Dense) {
    let a = int_matrix(128, 1500, 42);
    let b = Dense::from_fn(128, 8, |i, j| ((i * 7 + j * 5) % 9) as f32 - 4.0);
    let x = Dense::from_fn(128, 8, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
    let y = Dense::from_fn(128, 8, |i, j| ((i * 3 + j * 11) % 7) as f32 - 3.0);
    (a, b, x, y)
}

/// Two plans are interchangeable if they split rows identically, route the
/// same volume, agree on hierarchy, and execute to the same bits.
fn assert_plans_equivalent(old: &DistSpmm, new: &DistSpmm, b: &Dense, label: &str) {
    assert_eq!(old.part.starts, new.part.starts, "{label}: partition differs");
    assert_eq!(
        old.plan.total_volume(b.ncols),
        new.plan.total_volume(b.ncols),
        "{label}: plan volume differs"
    );
    assert_eq!(old.sched.is_some(), new.sched.is_some(), "{label}: hierarchy differs");
    let (c_old, _) = old.execute_with(b, &NativeKernel, &ExecOpts::default());
    let (c_new, _) = new
        .execute(&ExecRequest::spmm(b))
        .expect("thread-backend SpMM")
        .into_dense();
    assert_eq!(c_old.data, c_new.data, "{label}: executed bits differ");
}

#[test]
fn plan_shims_match_plan_spec() {
    let (a, b, _, _) = fixtures();
    let old = DistSpmm::plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(4), true);
    let new = PlanSpec::new(Topology::tsubame4(4)).plan(&a);
    assert_plans_equivalent(&old, &new, &b, "plan");

    let params = PlanParams { n_dense: 8, ..Default::default() };
    let old = DistSpmm::plan_with_params(
        &a,
        Strategy::Adaptive,
        Topology::tsubame4(4),
        false,
        &params,
    );
    let new = PlanSpec::new(Topology::tsubame4(4))
        .strategy(Strategy::Adaptive)
        .flat()
        .params(params.clone())
        .plan(&a);
    assert_plans_equivalent(&old, &new, &b, "plan_with_params");

    for partitioner in Partitioner::ALL {
        let old = DistSpmm::plan_partitioned(
            &a,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(4),
            true,
            &PlanParams::default(),
            partitioner,
        );
        let new = PlanSpec::new(Topology::tsubame4(4)).partitioner(partitioner).plan(&a);
        assert_plans_equivalent(&old, &new, &b, partitioner.name());
    }
}

#[test]
fn plan_adaptive_cached_matches_plan_spec_cached() {
    let (a, b, _, _) = fixtures();
    let params = PlanParams { n_dense: 8, ..Default::default() };
    let mut cache_old = PlanCache::in_memory();
    let mut cache_new = PlanCache::in_memory();
    let old = DistSpmm::plan_adaptive_cached(
        &a,
        Topology::tsubame4(4),
        true,
        &params,
        &mut cache_old,
    );
    let new = PlanSpec::new(Topology::tsubame4(4))
        .strategy(Strategy::Adaptive)
        .params(params.clone())
        .plan_cached(&a, &mut cache_new);
    assert_plans_equivalent(&old, &new, &b, "plan_adaptive_cached");
    // Both routes key the cache identically: each path's second lookup
    // hits, and they hit on each other's entries too.
    assert_eq!((cache_old.hits, cache_old.misses), (cache_new.hits, cache_new.misses));
    DistSpmm::plan_adaptive_cached(&a, Topology::tsubame4(4), true, &params, &mut cache_new);
    assert_eq!(cache_new.hits, 1, "shim missed the builder-written cache entry");
}

#[test]
fn plan_transpose_matches_transposed() {
    let (a, b, _, _) = fixtures();
    let d = PlanSpec::new(Topology::tsubame4(4)).plan(&a);
    let old = d.plan_transpose();
    let new = d.transposed();
    assert_plans_equivalent(&old, &new, &b, "plan_transpose");
}

#[test]
fn execute_shims_match_exec_requests_bitwise() {
    let (a, b, x, y) = fixtures();
    let d = PlanSpec::new(Topology::tsubame4(4)).plan(&a);
    for opts in [ExecOpts::default(), ExecOpts::sequential()] {
        let (c_old, s_old) = d.execute_with(&b, &NativeKernel, &opts);
        let (c_new, s_new) = d
            .execute(&ExecRequest::spmm(&b).opts(opts))
            .expect("thread-backend SpMM")
            .into_dense();
        assert_eq!(c_old.data, c_new.data, "execute_with({opts:?}): bits differ");
        assert_eq!(s_old.measured_volume(), s_new.measured_volume());

        let (e_old, _) = d.execute_sddmm_with(&x, &y, &NativeKernel, &opts);
        let (e_new, _) = d
            .execute(&ExecRequest::sddmm(&x, &y).opts(opts))
            .expect("thread-backend SDDMM")
            .into_sparse();
        assert_eq!(e_old, e_new, "execute_sddmm_with({opts:?}): bits differ");

        let (f_old, _) = d.execute_fused_with(&x, &y, &NativeKernel, &opts);
        let (f_new, _) = d
            .execute(&ExecRequest::fused(&x, &y).opts(opts))
            .expect("thread-backend fused kernel")
            .into_dense();
        assert_eq!(f_old.data, f_new.data, "execute_fused_with({opts:?}): bits differ");
    }
    // Default-options shims.
    let (e_old, _) = d.execute_sddmm(&x, &y, &NativeKernel);
    let (e_new, _) =
        d.execute(&ExecRequest::sddmm(&x, &y)).expect("thread-backend SDDMM").into_sparse();
    assert_eq!(e_old, e_new, "execute_sddmm: bits differ");
    let (f_old, _) = d.execute_fused(&x, &y, &NativeKernel);
    let (f_new, _) =
        d.execute(&ExecRequest::fused(&x, &y)).expect("thread-backend fused").into_dense();
    assert_eq!(f_old.data, f_new.data, "execute_fused: bits differ");
}

#[test]
fn proc_shims_match_proc_backend_requests_bitwise() {
    let popts = ProcOpts {
        timeout: Duration::from_secs(60),
        worker_exe: Some(env!("CARGO_BIN_EXE_shiro").into()),
        fault: None,
        pool: None,
    };
    let (a, b, x, y) = fixtures();
    let d = PlanSpec::new(Topology::tsubame4(2)).plan(&a);
    let opts = ExecOpts::default();
    let (c_old, _) = d.execute_proc(&b, &opts, &popts).expect("proc shim failed");
    let (c_new, _) = d
        .execute(&ExecRequest::spmm(&b).opts(opts).backend(Backend::Proc(popts.clone())))
        .expect("proc request failed")
        .into_dense();
    assert_eq!(c_old.data, c_new.data, "execute_proc: bits differ");

    let (f_old, _) = d.execute_fused_proc(&x, &y, &opts, &popts).expect("fused proc shim failed");
    let (f_new, _) = d
        .execute(&ExecRequest::fused(&x, &y).opts(opts).backend(Backend::Proc(popts)))
        .expect("fused proc request failed")
        .into_dense();
    assert_eq!(f_old.data, f_new.data, "execute_fused_proc: bits differ");
}

#[test]
fn dist_sddmm_wrapper_matches_exec_requests_bitwise() {
    let (a, _, x, y) = fixtures();
    let topo = Topology::tsubame4(4);
    let wrapper = DistSddmm::plan(&a, Strategy::Joint(Solver::Koenig), topo.clone(), true);
    let d = PlanSpec::new(topo).plan(&a);

    let (e_old, _) = wrapper.execute(&x, &y, &NativeKernel);
    let (e_default, _) =
        d.execute(&ExecRequest::sddmm(&x, &y)).expect("thread-backend SDDMM").into_sparse();
    assert_eq!(e_old, e_default, "DistSddmm::execute: bits differ");

    let opts = ExecOpts::sequential();
    let (e_old, _) = wrapper.execute_with(&x, &y, &NativeKernel, &opts);
    let (e_seq, _) = d
        .execute(&ExecRequest::sddmm(&x, &y).opts(opts))
        .expect("thread-backend SDDMM")
        .into_sparse();
    assert_eq!(e_old, e_seq, "DistSddmm::execute_with: bits differ");

    let (f_old, _) = wrapper.execute_fused(&x, &y, &NativeKernel);
    let (f_new, _) =
        d.execute(&ExecRequest::fused(&x, &y)).expect("thread-backend fused").into_dense();
    assert_eq!(f_old.data, f_new.data, "DistSddmm::execute_fused: bits differ");

    // from_spmm shares the plan verbatim; into_session hands the same
    // frozen programs to the session path.
    assert_eq!(wrapper.dist().part.starts, d.part.starts);
    let wrapped = DistSddmm::from_spmm(d);
    let mut sess = wrapped.into_session(ExecOpts::default(), true);
    let (e_sess, _) = sess
        .execute(&ExecRequest::sddmm(&x, &y))
        .expect("thread-backend SDDMM")
        .into_sparse();
    assert_eq!(e_sess, e_default, "DistSddmm::into_session: bits differ");
}

#[test]
fn session_shims_match_session_requests_bitwise() {
    let (a, b, x, y) = fixtures();
    let mut sess = PlanSpec::new(Topology::tsubame4(4))
        .plan(&a)
        .into_session(ExecOpts::default(), true);

    let (e_old, _) = sess.execute_sddmm(&x, &y, &NativeKernel);
    let (e_new, _) = sess
        .execute(&ExecRequest::sddmm(&x, &y))
        .expect("thread-backend SDDMM")
        .into_sparse();
    assert_eq!(e_old, e_new, "SpmmSession::execute_sddmm: bits differ");

    let (f_old, _) = sess.execute_fused(&x, &y, &NativeKernel);
    let (f_new, _) = sess
        .execute(&ExecRequest::fused(&x, &y))
        .expect("thread-backend fused kernel")
        .into_dense();
    assert_eq!(f_old.data, f_new.data, "SpmmSession::execute_fused: bits differ");

    let mut out_old = Dense::zeros(a.nrows, y.ncols);
    let _ = sess.execute_fused_into(&x, &y, &NativeKernel, &mut out_old);
    let mut out_new = Dense::zeros(a.nrows, y.ncols);
    sess.execute_into(&ExecRequest::fused(&x, &y), &mut out_new)
        .expect("thread-backend fused kernel");
    assert_eq!(out_old.data, out_new.data, "SpmmSession::execute_fused_into: bits differ");

    // The request path serves SpMM off the same session too.
    let (c_sess, _) =
        sess.execute(&ExecRequest::spmm(&b)).expect("thread-backend SpMM").into_dense();
    let (c_dist, _) = PlanSpec::new(Topology::tsubame4(4))
        .plan(&a)
        .execute(&ExecRequest::spmm(&b))
        .expect("thread-backend SpMM")
        .into_dense();
    assert_eq!(c_sess.data, c_dist.data, "session vs one-shot SpMM: bits differ");
}
