//! Edge-case integration tests: degenerate topologies, extreme shapes,
//! and config-file round trips.

use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::{self, kernel::NativeKernel};
use shiro::hierarchy;
use shiro::partition::{split_1d, Partitioner, RowPartition};
use shiro::sparse::gen;
use shiro::spmm::{DistSpmm, ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::rng::Rng;

fn joint_plan(a: &shiro::sparse::Csr, topo: Topology) -> DistSpmm {
    PlanSpec::new(topo).strategy(Strategy::Joint(Solver::Koenig)).plan(a)
}

fn verify(d: &DistSpmm, a: &shiro::sparse::Csr, n_dense: usize) {
    let mut rng = Rng::new(5);
    let b = Dense::random(a.nrows, n_dense, &mut rng);
    let (got, _) = d
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    let want = a.spmm(&b);
    assert!(want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30) < 1e-3);
}

#[test]
fn single_group_hierarchy_degenerates_to_direct() {
    // 4 ranks on tsubame (one node): hierarchy must produce only direct
    // transfers and still be exact.
    let a = gen::rmat(256, 3000, (0.5, 0.2, 0.2), false, 1);
    let d = joint_plan(&a, Topology::tsubame4(4));
    let sched = d.sched.as_ref().unwrap();
    assert!(sched.b_flows.is_empty());
    assert!(sched.c_flows.is_empty());
    assert_eq!(sched.inter_group_bytes(32), 0);
    verify(&d, &a, 32);
}

#[test]
fn group_size_one_all_inter() {
    // group_size 1: every pair is inter-group; dedup can't help B (one
    // consumer per flow) and aggregation can't help C (one producer) —
    // schedule must collapse to single-hop transfers and stay exact.
    let a = gen::powerlaw(256, 3000, 1.4, 2);
    let mut topo = Topology::tsubame4(8);
    topo.group_size = 1;
    let d = joint_plan(&a, topo);
    let sched = d.sched.as_ref().unwrap();
    for f in &sched.b_flows {
        assert_eq!(f.consumers.len(), 1);
        assert_eq!(f.rep, f.consumers[0].0, "single consumer must be its own rep");
    }
    for f in &sched.c_flows {
        assert_eq!(f.producers.len(), 1);
    }
    verify(&d, &a, 8);
}

#[test]
fn huge_rank_count_tiny_matrix() {
    // More ranks than meaningful work: 64 ranks on 128 rows (2 rows each).
    let a = gen::erdos_renyi(128, 128, 700, 3);
    let d = joint_plan(&a, Topology::tsubame4(64));
    verify(&d, &a, 4);
}

#[test]
fn wide_dense_matrix() {
    // N = 256 (wider than any artifact; native path).
    let a = gen::rmat(128, 1200, (0.5, 0.2, 0.2), false, 4);
    let d = joint_plan(&a, Topology::tsubame4(8));
    verify(&d, &a, 256);
}

#[test]
fn fully_dense_block_matrix() {
    // Dense A: covers degenerate "everything needed everywhere".
    let mut coo = shiro::sparse::Coo::new(64, 64);
    let mut rng = Rng::new(6);
    for r in 0..64 {
        for c in 0..64 {
            coo.push(r, c, rng.f32() + 0.01);
        }
    }
    let a = coo.to_csr();
    let d = joint_plan(&a, Topology::tsubame4(8));
    // Joint volume can't beat min(rows, cols) per block here; exactness is
    // the point.
    verify(&d, &a, 8);
}

/// Run a plan end-to-end on an explicit (possibly degenerate) partition,
/// flat and hierarchical, and verify against the serial reference.
fn verify_partition(a: &shiro::sparse::Csr, part: &RowPartition, ranks: usize) {
    let blocks = split_1d(a, part);
    let plan = comm::plan(&blocks, part, Strategy::Joint(Solver::Koenig), None);
    let topo = Topology::tsubame4(ranks);
    let mut rng = Rng::new(13);
    let b = Dense::random(a.nrows, 4, &mut rng);
    let want = a.spmm(&b);
    for sched in [None, Some(hierarchy::build(&plan, &topo))] {
        let (got, _) = exec::run(part, &plan, &blocks, sched.as_ref(), &topo, &b, &NativeKernel);
        let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
        assert!(err < 1e-3, "starts {:?}: rel err {err}", part.starts);
    }
}

#[test]
fn partition_with_zero_row_ranks() {
    // Explicit empty ranks (including rank 0 and the last rank): the
    // executor must neither hang waiting on them nor panic on zero-height
    // blocks.
    let a = gen::rmat(64, 800, (0.55, 0.2, 0.19), false, 17);
    let part = RowPartition::from_starts(vec![0, 0, 20, 20, 20, 45, 64, 64, 64]);
    assert_eq!(part.nparts, 8);
    verify_partition(&a, &part, 8);
}

#[test]
fn partition_more_ranks_than_rows() {
    // 12 ranks over an 8-row matrix: every partitioner must yield a valid
    // 12-part split (with empty ranks) that executes exactly.
    let a = gen::erdos_renyi(8, 8, 40, 19);
    let topo = Topology::tsubame4(12);
    for partitioner in Partitioner::ALL {
        let part = partitioner.partition(&a, 12, &topo, 4);
        assert_eq!(part.nparts, 12);
        verify_partition(&a, &part, 12);
    }
}

#[test]
fn partition_single_row_blocks() {
    // One row per rank — the minimum non-empty block height everywhere.
    let a = gen::erdos_renyi(8, 8, 30, 23);
    let part = RowPartition::from_starts((0..=8).collect());
    assert_eq!(part.nparts, 8);
    assert!((0..8).all(|p| part.len(p) == 1));
    verify_partition(&a, &part, 8);
}

#[test]
fn all_nnz_in_one_rank() {
    // Every nonzero is concentrated in four hot rows, so one rank owns all
    // the compute: the others only serve B rows (or nothing at all) and
    // the executor must still terminate without hanging on ranks that
    // neither send nor receive.
    let mut coo = shiro::sparse::Coo::new(32, 32);
    for r in 8..12 {
        for c in 0..32 {
            coo.push(r, c, ((r + c) % 5) as f32 + 1.0);
        }
    }
    let a = coo.to_csr();
    let topo = Topology::tsubame4(8);
    for partitioner in Partitioner::ALL {
        let part = partitioner.partition(&a, 8, &topo, 4);
        assert_eq!(
            shiro::partition::rank_nnz(&a, &part).iter().sum::<u64>(),
            a.nnz() as u64
        );
        verify_partition(&a, &part, 8);
    }
}

/// Run distributed SDDMM end-to-end on an explicit (possibly degenerate)
/// partition, flat and hierarchical, and require **bitwise** equality with
/// the serial oracle (legitimate on any input: one producer per entry).
fn verify_sddmm_partition(a: &shiro::sparse::Csr, part: &RowPartition, ranks: usize) {
    let blocks = split_1d(a, part);
    let plan = comm::plan(&blocks, part, Strategy::Joint(Solver::Koenig), None);
    let topo = Topology::tsubame4(ranks);
    let mut rng = Rng::new(29);
    let x = Dense::random(a.nrows, 4, &mut rng);
    let y = Dense::random(a.nrows, 4, &mut rng);
    let want = a.sddmm(&x, &y);
    for sched in [None, Some(hierarchy::build(&plan, &topo))] {
        let (got, _) = exec::run_sddmm_with(
            part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &x,
            &y,
            &NativeKernel,
            &shiro::exec::ExecOpts::default(),
        );
        assert_eq!(got, want, "starts {:?}", part.starts);
    }
}

#[test]
fn sddmm_partition_with_zero_row_ranks() {
    // Empty ranks (including rank 0 and the last): no hangs on ranks that
    // neither post B/X rows nor expect any, and exact assembly around the
    // holes.
    let a = gen::rmat(64, 800, (0.55, 0.2, 0.19), false, 17);
    let part = RowPartition::from_starts(vec![0, 0, 20, 20, 20, 45, 64, 64, 64]);
    assert_eq!(part.nparts, 8);
    verify_sddmm_partition(&a, &part, 8);
}

#[test]
fn sddmm_more_ranks_than_rows() {
    let a = gen::erdos_renyi(8, 8, 40, 19);
    let topo = Topology::tsubame4(12);
    for partitioner in Partitioner::ALL {
        let part = partitioner.partition(&a, 12, &topo, 4);
        verify_sddmm_partition(&a, &part, 12);
    }
}

#[test]
fn sddmm_all_nnz_in_one_rank() {
    // One rank owns every nonzero: the others only ship dense rows (or
    // nothing), and row-serving collapses onto one side.
    let mut coo = shiro::sparse::Coo::new(32, 32);
    for r in 8..12 {
        for c in 0..32 {
            coo.push(r, c, ((r + c) % 5) as f32 + 1.0);
        }
    }
    let a = coo.to_csr();
    let topo = Topology::tsubame4(8);
    for partitioner in Partitioner::ALL {
        let part = partitioner.partition(&a, 8, &topo, 4);
        verify_sddmm_partition(&a, &part, 8);
    }
}

#[test]
fn sddmm_empty_pattern_rows_and_empty_matrix() {
    // Structurally empty rows contribute no entries anywhere in the
    // pipeline; the all-empty matrix exchanges nothing and assembles an
    // all-empty result.
    let mut coo = shiro::sparse::Coo::new(48, 48);
    for r in (0..48).step_by(3) {
        coo.push(r, (r * 11) % 48, 1.5);
    }
    let a = coo.to_csr(); // two of every three rows empty
    let part = RowPartition::balanced(48, 6);
    verify_sddmm_partition(&a, &part, 6);

    let z = shiro::sparse::Csr::zeros(32, 32);
    let part = RowPartition::balanced(32, 4);
    verify_sddmm_partition(&z, &part, 4);
}

#[test]
fn coo_duplicate_summing_feeds_sddmm_deterministically() {
    // Pin the contract: Coo::to_csr sums duplicate coordinates FIRST, and
    // SDDMM scales the summed value — the distributed engine sees exactly
    // one entry per coordinate and stays bitwise-equal to the oracle.
    let mut coo = shiro::sparse::Coo::new(16, 16);
    for i in 0..16usize {
        coo.push(i, (i * 5) % 16, 1.25);
        coo.push(i, (i * 5) % 16, 2.5); // duplicate, summed to 3.75
        coo.push((i * 3) % 16, i, -0.5);
    }
    let a = coo.to_csr();
    // Duplicates collapsed before any kernel sees them.
    assert!(a.nnz() < 48);
    let mut rng = Rng::new(31);
    let x = Dense::random(16, 3, &mut rng);
    let y = Dense::random(16, 3, &mut rng);
    let want = a.sddmm(&x, &y);
    let d = joint_plan(&a, Topology::tsubame4(4));
    let (got, _) = d
        .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
        .expect("thread-backend SDDMM")
        .into_sparse();
    assert_eq!(got, want);
    // A purely-duplicate coordinate really carries the summed value
    // (row 1 col 5 collects only the two pushes from i = 1).
    let k = a.row_indices(1).iter().position(|&c| c == 5).unwrap();
    assert_eq!(a.row_values(1)[k], 3.75);
}

#[test]
fn config_file_roundtrip_drives_run() {
    // The shipped sample config parses and resolves.
    let cfg = shiro::util::toml_mini::Config::load(std::path::Path::new("run.toml")).unwrap();
    assert_eq!(cfg.str_or("run.dataset", ""), "GAP-web");
    assert_eq!(cfg.int_or("run.ranks", 0), 32);
    assert_eq!(cfg.str_or("run.topo", ""), "tsubame4");
    assert_eq!(cfg.str_or("run.partitioner", ""), "nnz-balanced");
}

#[test]
fn simulate_zero_byte_stage() {
    use shiro::sim::{simulate, SimJob, SimMsg, Stage};
    let topo = Topology::flat(2, 1e9);
    let job = SimJob {
        stages: vec![Stage::comm("z", vec![SimMsg { src: 0, dst: 1, bytes: 0 }])],
    };
    let r = simulate(&job, &topo);
    // Latency-only message.
    assert!(r.total > 0.0 && r.total < 1e-4);
}

#[test]
fn sim_trace_on_real_plan() {
    use shiro::sim::trace::{to_chrome_json, trace};
    let a = gen::rmat(256, 3000, (0.5, 0.2, 0.2), false, 7);
    let d = joint_plan(&a, Topology::tsubame4(16));
    let job = d.sim_job(32);
    let t = trace(&job, &d.topo);
    assert!(!t.is_empty());
    let json = to_chrome_json(&t, &job);
    assert!(json.contains("stageI"));
}
