//! Crash-recovery differential suite (DESIGN.md §12): deterministic fault
//! injection against the proc backend's replan-over-survivors recovery.
//!
//! The load-bearing assertion everywhere: after losing a rank mid-step,
//! the recovered C must be **bitwise identical** to a cold run on the
//! post-recovery partition (pinned by `RecoveryReport::final_starts`) —
//! recovery replays the same pure `partition → plan → hierarchy → execute`
//! pipeline a cold start runs, and the canonical (origin, row) fold makes
//! proc and thread backends interchangeable oracles. Inputs are
//! integer-exact, so the serial `Csr::spmm`/`Csr::sddmm` oracle matches
//! bit for bit too, regardless of how the partition shifted.
//!
//! Worker processes are this crate's own binary (re-entered through
//! `maybe_run_worker`), located via `CARGO_BIN_EXE_shiro`.

use std::time::{Duration, Instant};

use shiro::bench::int_matrix;
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::hierarchy;
use shiro::partition::{split_1d, Partitioner, RowPartition};
use shiro::runtime::multiproc::{
    CrashPhase, FailureCause, FaultPlan, FaultPolicy, PoolHandle, ProcOpts, RecoveryReport,
};
use shiro::serve::{Server, ServeConfig, ServeRequest};
use shiro::sparse::Csr;
use shiro::spmm::{Backend, DistSpmm, ExecError, ExecRequest, PlanSpec};
use shiro::topology::Topology;

fn popts(fault: Option<FaultPlan>) -> ProcOpts {
    ProcOpts {
        timeout: Duration::from_secs(60),
        worker_exe: Some(env!("CARGO_BIN_EXE_shiro").into()),
        fault,
        pool: None,
    }
}

fn plan(a: &Csr, strategy: Strategy, ranks: usize, hier: bool) -> DistSpmm {
    PlanSpec::new(Topology::tsubame4(ranks)).strategy(strategy).hierarchical(hier).plan(a)
}

fn int_b(n: usize, k: usize) -> Dense {
    Dense::from_fn(n, k, |i, j| ((i * 7 + j * 5) % 9) as f32 - 4.0)
}

fn int_xy(n: usize, k: usize) -> (Dense, Dense) {
    let x = Dense::from_fn(n, k, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
    let y = Dense::from_fn(n, k, |i, j| ((i * 3 + j * 11) % 7) as f32 - 3.0);
    (x, y)
}

/// Rebuild the exact plan state recovery compiled, as a cold start: the
/// same pure function of (A, partition, strategy, topology) the collector
/// runs after a loss. Executing this on the thread backend is the bitwise
/// oracle for the recovered proc run.
fn cold_dist(a: &Csr, starts: &[usize], strategy: Strategy, hier: bool) -> DistSpmm {
    let part = RowPartition::from_starts(starts.to_vec());
    let blocks = split_1d(a, &part);
    let plan = comm::plan(&blocks, &part, strategy, None);
    let topo = Topology::tsubame4(part.nparts);
    let sched = hier.then(|| hierarchy::build(&plan, &topo));
    DistSpmm { part, blocks, plan, sched, rep: None, topo, prep_secs: 0.0 }
}

/// One recovered SpMM run: returns (C, report), asserting the report's
/// internal consistency on the way out.
fn run_recovered(
    d: &DistSpmm,
    b: &Dense,
    fault: FaultPlan,
    max_retries: usize,
    label: &str,
) -> (Dense, RecoveryReport) {
    let r = d
        .execute(
            &ExecRequest::spmm(b)
                .backend(Backend::Proc(popts(Some(fault))))
                .fault_policy(FaultPolicy::Recover { max_retries }),
        )
        .unwrap_or_else(|f| panic!("{label}: recovery failed: {f}"));
    let rec = r.recovery.clone().unwrap_or_else(|| panic!("{label}: no recovery report"));
    assert!(rec.recovered, "{label}: report not marked recovered");
    assert_eq!(rec.replans, rec.lost_ranks.len(), "{label}: replans != losses");
    assert_eq!(rec.replan_secs.len(), rec.replans, "{label}: missing latency samples");
    assert!(rec.latency().1 > 0.0, "{label}: zero total replan time");
    let (c, _) = r.into_dense();
    (c, rec)
}

#[test]
fn recovery_matrix_strategies_by_phase() {
    // Every strategy × every crash phase, 4 ranks, killing rank 1 under
    // Recover{1}: the run must converge in exactly one replan, and the
    // result must be bitwise both the cold-start oracle on the surviving
    // partition and the serial oracle.
    let a = int_matrix(128, 1500, 42);
    let b = int_b(128, 6);
    let want = a.spmm(&b);
    for strategy in
        [Strategy::Block, Strategy::Column, Strategy::Row, Strategy::Joint(Solver::Koenig)]
    {
        let hier = strategy != Strategy::Block;
        let d = plan(&a, strategy, 4, hier);
        for phase in CrashPhase::ALL {
            let label = format!("{strategy:?}/{}", phase.name());
            let (c, rec) = run_recovered(&d, &b, FaultPlan::new(1, phase), 1, &label);
            assert_eq!(rec.lost_ranks, vec![1], "{label}: wrong loss attribution");
            assert_eq!(rec.final_starts.len(), 4, "{label}: expected 3 surviving ranks");
            assert_eq!(c.data, want.data, "{label}: bits differ from serial oracle");
            let cold = cold_dist(&a, &rec.final_starts, strategy, hier);
            let (c_cold, _) = cold
                .execute(&ExecRequest::spmm(&b))
                .expect("thread backend")
                .into_dense();
            assert_eq!(c.data, c_cold.data, "{label}: bits differ from cold post-recovery run");
        }
    }
}

#[test]
fn recovery_across_partitioners_and_rank_counts() {
    // Partitioner × rank-count sweep with the crash phase cycled. Includes
    // the 2-rank edge (recovery leaves a single survivor running the whole
    // matrix with an empty comm plan) and the 8-rank two-group case where
    // the shrunken topology re-draws the group boundary.
    let a = int_matrix(160, 1800, 7);
    let b = int_b(160, 4);
    let want = a.spmm(&b);
    for (pi, partitioner) in Partitioner::ALL.into_iter().enumerate() {
        for (ri, ranks) in [2usize, 4, 8].into_iter().enumerate() {
            let phase = CrashPhase::ALL[(pi + ri) % CrashPhase::ALL.len()];
            let d = PlanSpec::new(Topology::tsubame4(ranks))
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(true)
                .partitioner(partitioner)
                .plan(&a);
            let label = format!("{}/{ranks} ranks/{}", partitioner.name(), phase.name());
            let (c, rec) =
                run_recovered(&d, &b, FaultPlan::new(ranks / 2, phase), 1, &label);
            assert_eq!(rec.lost_ranks, vec![ranks / 2], "{label}: wrong loss attribution");
            assert_eq!(rec.final_starts.len(), ranks, "{label}: expected ranks-1 survivors");
            assert_eq!(c.data, want.data, "{label}: bits differ from serial oracle");
            let cold = cold_dist(&a, &rec.final_starts, Strategy::Joint(Solver::Koenig), true);
            let (c_cold, _) = cold
                .execute(&ExecRequest::spmm(&b))
                .expect("thread backend")
                .into_dense();
            assert_eq!(c.data, c_cold.data, "{label}: bits differ from cold post-recovery run");
        }
    }
}

#[test]
fn recovery_on_flat_plans() {
    // No hierarchical schedule anywhere: the replan must stay flat too
    // (`had_sched` is preserved, not re-decided).
    let a = int_matrix(128, 1400, 11);
    let b = int_b(128, 5);
    let want = a.spmm(&b);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, false);
    for phase in CrashPhase::ALL {
        let label = format!("flat/{}", phase.name());
        let (c, rec) = run_recovered(&d, &b, FaultPlan::new(2, phase), 1, &label);
        assert_eq!(c.data, want.data, "{label}: bits differ from serial oracle");
        let cold = cold_dist(&a, &rec.final_starts, Strategy::Joint(Solver::Koenig), false);
        let (c_cold, _) =
            cold.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
        assert_eq!(c.data, c_cold.data, "{label}: bits differ from cold post-recovery run");
    }
}

#[test]
fn sddmm_recovery_matches_serial_and_cold_oracles() {
    let a = int_matrix(128, 1400, 55);
    let (x, y) = int_xy(128, 4);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let r = d
        .execute(
            &ExecRequest::sddmm(&x, &y)
                .backend(Backend::Proc(popts(Some(FaultPlan::new(2, CrashPhase::PreDone)))))
                .fault_policy(FaultPolicy::Recover { max_retries: 1 }),
        )
        .expect("SDDMM recovery failed");
    let rec = r.recovery.clone().expect("no recovery report");
    let (e, _) = r.into_sparse();
    assert_eq!(e, a.sddmm(&x, &y), "recovered SDDMM differs from serial oracle");
    // E is assembled under the *final* partition; a cold run there must
    // agree frame for frame.
    let cold = cold_dist(&a, &rec.final_starts, Strategy::Joint(Solver::Koenig), true);
    let (e_cold, _) =
        cold.execute(&ExecRequest::sddmm(&x, &y)).expect("thread backend").into_sparse();
    assert_eq!(e, e_cold, "recovered SDDMM differs from cold post-recovery run");
}

#[test]
fn fused_recovery_matches_thread_and_cold_oracles() {
    let a = int_matrix(128, 1400, 77);
    let (x, y) = int_xy(128, 4);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let (c_thread, _) =
        d.execute(&ExecRequest::fused(&x, &y)).expect("thread backend").into_dense();
    let r = d
        .execute(
            &ExecRequest::fused(&x, &y)
                .backend(Backend::Proc(popts(Some(FaultPlan::new(1, CrashPhase::MidExchange)))))
                .fault_policy(FaultPolicy::Recover { max_retries: 1 }),
        )
        .expect("fused recovery failed");
    let rec = r.recovery.clone().expect("no recovery report");
    let (c, _) = r.into_dense();
    // Integer-exact inputs make the fused output partition-independent, so
    // the pre-loss thread run is also a bitwise oracle.
    assert_eq!(c.data, c_thread.data, "recovered fused bits differ from thread run");
    let cold = cold_dist(&a, &rec.final_starts, Strategy::Joint(Solver::Koenig), true);
    let (c_cold, _) =
        cold.execute(&ExecRequest::fused(&x, &y)).expect("thread backend").into_dense();
    assert_eq!(c.data, c_cold.data, "recovered fused bits differ from cold run");
}

#[test]
fn killed_worker_is_readmitted_between_requests() {
    // Recovery composes with the persistent pool: a worker lost mid-request
    // is quarantined and the request replans over the survivors; at the
    // *next* request on the same handle the pool respawns the dead slot,
    // re-admits it through a fresh HELLO, and serves the full original
    // rank count again — bitwise, with exactly one extra spawn.
    let a = int_matrix(128, 1500, 42);
    let b = int_b(128, 4);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let (c_thread, _) =
        d.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();

    let pool = PoolHandle::new();
    let pooled = |fault: Option<FaultPlan>| {
        Backend::Proc(ProcOpts { pool: Some(pool.clone()), ..popts(fault) })
    };

    // Request 1: rank 1 dies post-decode; replan over the 3 survivors.
    let r = d
        .execute(
            &ExecRequest::spmm(&b)
                .backend(pooled(Some(FaultPlan::post_decode(1))))
                .fault_policy(FaultPolicy::Recover { max_retries: 1 }),
        )
        .expect("recovery over survivors failed");
    let rec = r.recovery.clone().expect("no recovery report");
    assert_eq!(rec.lost_ranks, vec![1], "wrong loss attribution");
    assert_eq!(rec.final_starts.len(), 4, "expected 3 surviving ranks");
    let (c1, _) = r.into_dense();
    assert_eq!(c1.data, c_thread.data, "recovered request: bits differ from thread oracle");
    let s = pool.stats();
    assert_eq!(s.spawns, 4, "the kill itself must not trigger a mid-request respawn");
    assert_eq!(s.readmissions, 0, "re-admission happens between requests, not during");

    // Request 2: clean. The pool heals to 4 live workers and the request
    // plans at the original rank count as if nothing happened.
    let r2 = d
        .execute(&ExecRequest::spmm(&b).backend(pooled(None)))
        .expect("post-readmission request failed");
    assert!(r2.recovery.is_none(), "healed fleet must not report recovery");
    let (c2, _) = r2.into_dense();
    assert_eq!(c2.data, c_thread.data, "healed request: bits differ from thread oracle");
    let s = pool.stats();
    assert_eq!(s.spawns, 5, "exactly one respawn for the killed rank");
    assert_eq!(s.readmissions, 1, "one dead slot re-admitted");
    assert_eq!(s.reuses, 1, "survivors' live connections are reused");
}

/// Assert `err` is the structured kill-report `multiproc_suite.rs` pins:
/// right rank, a death-shaped cause, and well inside the deadline.
fn assert_kill_failure(err: ExecError, rank: usize, wall: Duration) {
    let err = match err {
        ExecError::Rank(f) => f,
        other => panic!("expected a structured RankFailure, got {other}"),
    };
    assert_eq!(err.rank, rank, "failure must be attributed to the killed rank: {err}");
    assert!(
        matches!(
            err.cause,
            FailureCause::Disconnected(_)
                | FailureCause::HeartbeatTimeout(_)
                | FailureCause::Worker(_)
        ),
        "unexpected cause: {err}"
    );
    assert!(wall < Duration::from_secs(30), "failure took {wall:?} — parent nearly hung");
}

#[test]
fn fault_policy_fail_surfaces_rank_failure() {
    // The default policy must stay bitwise the pre-recovery behavior: a
    // mid-exchange death surfaces the exact structured RankFailure the
    // multiproc suite pins, with no replan attempt.
    let a = int_matrix(128, 1500, 3);
    let b = int_b(128, 4);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let popts = ProcOpts {
        timeout: Duration::from_secs(10),
        ..popts(Some(FaultPlan::new(1, CrashPhase::MidExchange)))
    };
    let t0 = Instant::now();
    let err = d
        .execute(&ExecRequest::spmm(&b).backend(Backend::Proc(popts)))
        .expect_err("run with a killed worker must fail under FaultPolicy::Fail");
    assert_kill_failure(err, 1, t0.elapsed());
}

#[test]
fn recover_with_zero_retries_behaves_like_fail() {
    let a = int_matrix(128, 1500, 3);
    let b = int_b(128, 4);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let popts = ProcOpts {
        timeout: Duration::from_secs(10),
        ..popts(Some(FaultPlan::post_decode(1)))
    };
    let t0 = Instant::now();
    let err = d
        .execute(
            &ExecRequest::spmm(&b)
                .backend(Backend::Proc(popts))
                .fault_policy(FaultPolicy::Recover { max_retries: 0 }),
        )
        .expect_err("zero retries must surface the failure");
    assert_kill_failure(err, 1, t0.elapsed());
}

#[test]
fn losing_every_worker_returns_structured_failure() {
    // One rank, and it dies: recovery has no survivors to replan over, so
    // even a generous retry budget must surface a structured failure —
    // never hang, never panic the control plane.
    let a = int_matrix(96, 900, 9);
    let b = int_b(96, 3);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 1, false);
    let popts = ProcOpts {
        timeout: Duration::from_secs(10),
        ..popts(Some(FaultPlan::post_decode(0)))
    };
    let t0 = Instant::now();
    let err = d
        .execute(
            &ExecRequest::spmm(&b)
                .backend(Backend::Proc(popts))
                .fault_policy(FaultPolicy::Recover { max_retries: 3 }),
        )
        .expect_err("losing the last worker must fail");
    assert_kill_failure(err, 0, t0.elapsed());
}

#[test]
#[ignore = "chaos soak — run with --ignored in CI's perf-smoke lane"]
fn chaos_soak_serve_session_with_seeded_worker_kills() {
    // A serve session under tenant churn where every k-th request runs on
    // the proc backend with a seeded worker kill. With the server's
    // FaultPolicy::Recover, no request may be dropped, double-fulfilled,
    // or answered with different bits than a clean direct execute.
    const RANKS: usize = 4;
    const REQUESTS: usize = 24;
    const KILL_EVERY: usize = 4;
    let graphs: Vec<Csr> = (0..3).map(|i| int_matrix(96, 900 + 50 * i, 21 + i as u64)).collect();
    let mut cfg = ServeConfig::new(Topology::tsubame4(RANKS));
    cfg.workers = 0; // drive deterministically with drain_all
    cfg.fault_policy = FaultPolicy::Recover { max_retries: 2 };
    let mut srv = Server::new(cfg);
    for (i, a) in graphs.iter().enumerate() {
        srv.register_graph(&format!("g{i}"), a.clone());
    }
    let plans: Vec<DistSpmm> =
        graphs.iter().map(|a| PlanSpec::new(Topology::tsubame4(RANKS)).plan(a)).collect();

    let mut kills = 0;
    for i in 0..REQUESTS {
        let gi = i % graphs.len();
        let b = int_b(96, 2 + i % 4);
        let mut req = ServeRequest::spmm(&format!("g{gi}"), b.clone());
        if i % KILL_EVERY == 0 {
            req = req.backend(Backend::Proc(popts(Some(FaultPlan::seeded(i as u64, RANKS)))));
            kills += 1;
        }
        let t = srv.try_submit(req).unwrap_or_else(|e| panic!("request {i} rejected: {e}"));
        srv.drain_all();
        let resp = t.wait().unwrap_or_else(|e| panic!("request {i} dropped: {e}"));
        if i % KILL_EVERY == 0 {
            let rec = resp.recovery.clone().unwrap_or_else(|| panic!("request {i}: no report"));
            assert!(rec.recovered && rec.replans >= 1, "request {i}: kill did not recover");
        }
        let (want, _) = plans[gi]
            .execute(&ExecRequest::spmm(&b))
            .expect("thread-backend SpMM")
            .into_dense();
        assert_eq!(resp.into_dense().data, want.data, "request {i}: bits differ under chaos");
    }

    let stats = srv.shutdown();
    // Conservation: every submission is fulfilled exactly once.
    assert_eq!(stats.completed, REQUESTS as u64, "requests dropped or double-fulfilled");
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.latency().count, REQUESTS, "one latency sample per request");
    assert_eq!(stats.recoveries, kills as u64, "each seeded kill is one replan round");
    assert_eq!(stats.recovery_secs.len(), kills, "one recovery sample per replan");
    let (lat, total) = stats.recovery_latency();
    assert_eq!(lat.count, kills);
    assert!(total > 0.0 && lat.max <= total, "degenerate recovery latency stats");
}
