//! GNN training engine test suite (PR 4): finite-difference gradient
//! checks through the full distributed pipeline (forward Â sessions and
//! mirrored Âᵀ backward sessions), strict loss decrease on a learnable
//! toy target, bit-exact training determinism across every executor
//! configuration and session-reuse mode, the epoch-reuse amortization
//! contract, and the pinned `normalize_adj` edge-case behavior.

use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::exec::ExecOpts;
use shiro::gnn::{normalize_adj, Gcn, GcnConfig, NativeDense};
use shiro::sparse::{gen, Coo, Csr};
use shiro::topology::Topology;

fn tiny_cfg() -> GcnConfig {
    GcnConfig {
        feature_dim: 6,
        hidden_dim: 4,
        epochs: 1,
        lr: 0.0,
        log_every: 1,
        seed: 9,
    }
}

/// Central finite differences on the training loss vs the analytic
/// gradients from one forward+backward pass. Every product in the loss
/// runs through the distributed sessions, so this check fails if the
/// backward Âᵀ products are wrong — e.g. if an asymmetric adjacency were
/// backpropagated through Â instead of the mirrored transpose plan.
fn fd_gradient_check(adj: &Csr, label: &str) {
    let mut gcn = Gcn::new(
        adj,
        Strategy::Joint(Solver::Koenig),
        Topology::tsubame4(4),
        true,
        tiny_cfg(),
    );
    let (_, dw0, dw1) = gcn.loss_and_grads(&NativeKernel, &NativeDense);
    let eps = 1e-2f32;
    for which in 0..2 {
        let grads = if which == 0 { dw0.clone() } else { dw1.clone() };
        // Probe the largest-magnitude gradient entries: they carry the
        // signal and sit furthest from relu kinks and f32 noise floors.
        let mut idx: Vec<usize> = (0..grads.data.len()).collect();
        idx.sort_by(|&i, &j| {
            grads.data[j].abs().partial_cmp(&grads.data[i].abs()).unwrap()
        });
        let sample = &idx[..6.min(idx.len())];
        let mut bad = 0usize;
        for &i in sample {
            let orig = if which == 0 { gcn.w0.data[i] } else { gcn.w1.data[i] };
            let mut loss_at = |v: f32, gcn: &mut Gcn| -> f32 {
                if which == 0 {
                    gcn.w0.data[i] = v;
                } else {
                    gcn.w1.data[i] = v;
                }
                let (l, _, _) = gcn.loss_and_grads(&NativeKernel, &NativeDense);
                l
            };
            let lp = loss_at(orig + eps, &mut gcn);
            let lm = loss_at(orig - eps, &mut gcn);
            loss_at(orig, &mut gcn);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads.data[i];
            let tol = 1e-3 + 0.25 * an.abs().max(fd.abs());
            if (fd - an).abs() > tol {
                eprintln!("{label} w{which}[{i}]: fd {fd} vs analytic {an}");
                bad += 1;
            }
        }
        // Allow one relu-kink outlier per matrix; more means the chain
        // rule through the distributed products is broken.
        assert!(
            bad <= 1,
            "{label} w{which}: {bad}/{} finite-difference mismatches",
            sample.len()
        );
    }
}

#[test]
fn gradients_match_finite_differences_symmetric() {
    let adj = gen::rmat(32, 180, (0.5, 0.2, 0.2), true, 4);
    fd_gradient_check(&adj, "symmetric");
}

#[test]
fn gradients_match_finite_differences_asymmetric() {
    // Directed graph: Âᵀ ≠ Â. The backward products run through the
    // mirrored transpose plan; a plan that silently reused Â would shift
    // every gradient and fail here.
    let adj = gen::rmat(32, 180, (0.6, 0.25, 0.1), false, 6);
    let a_hat = normalize_adj(&adj);
    assert_ne!(
        a_hat.transpose().indices,
        a_hat.indices,
        "test graph must be asymmetric"
    );
    fd_gradient_check(&adj, "asymmetric");
}

#[test]
fn loss_strictly_decreasing_on_learnable_target() {
    // The synthetic target is one propagation of a random signal — squarely
    // learnable by a 2-layer GCN. Some learning rate in the sweep must give
    // a *strictly* decreasing full loss trajectory.
    let adj = gen::rmat(64, 500, (0.5, 0.2, 0.2), true, 11);
    let mut tried = Vec::new();
    for lr in [1.0f32, 0.5, 0.25, 0.1] {
        let cfg = GcnConfig { epochs: 15, log_every: 1, lr, ..Default::default() };
        let mut gcn = Gcn::new(
            &adj,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(4),
            true,
            cfg,
        );
        let r = gcn.train(&NativeKernel, &NativeDense);
        let ls: Vec<f32> = r.losses.iter().map(|(_, l)| *l).collect();
        assert_eq!(ls.len(), 15, "log_every=1 must record every epoch");
        let strictly_down = ls.windows(2).all(|w| w[1] < w[0]);
        let learned = ls[ls.len() - 1] < ls[0] * 0.9;
        if strictly_down && learned {
            return;
        }
        tried.push((lr, ls[0], ls[ls.len() - 1], strictly_down));
    }
    panic!("no learning rate gave a strictly decreasing loss: {tried:?}");
}

#[test]
fn training_trajectory_bit_identical_across_executor_configs() {
    // The full loss trajectory — 3 distributed products per epoch, every
    // epoch — must be bit-identical across overlap on/off, worker caps
    // 1/2/4/8, and session-reuse vs cold per-epoch execution. This is the
    // training-level face of the executor's canonical fold order.
    let adj = gen::rmat(96, 900, (0.55, 0.2, 0.19), true, 13);
    let cfg = GcnConfig { epochs: 4, log_every: 1, lr: 1.5, ..Default::default() };
    let new_gcn = || {
        Gcn::new(
            &adj,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(8),
            true,
            cfg.clone(),
        )
    };
    let bits = |losses: &[(usize, f32)]| -> Vec<(usize, u32)> {
        losses.iter().map(|(e, l)| (*e, l.to_bits())).collect()
    };
    let want = bits(&new_gcn().train(&NativeKernel, &NativeDense).losses);
    assert_eq!(want.len(), 4);
    // Overlap off and worker caps.
    let variants = [
        ExecOpts::sequential(),
        ExecOpts { workers: 1, ..ExecOpts::default() },
        ExecOpts { workers: 2, ..ExecOpts::default() },
        ExecOpts { workers: 4, ..ExecOpts::default() },
        ExecOpts { workers: 8, ..ExecOpts::default() },
    ];
    for opts in variants {
        let mut gcn = new_gcn();
        gcn.set_exec_opts(opts);
        let got = bits(&gcn.train(&NativeKernel, &NativeDense).losses);
        assert_eq!(got, want, "trajectory diverged under {opts:?}");
    }
    // Session reuse vs cold per-epoch execution (fresh plans every epoch).
    let got = bits(&new_gcn().train_cold(&NativeKernel, &NativeDense).losses);
    assert_eq!(got, want, "cold per-epoch execution diverged from sessions");
}

#[test]
fn session_reuse_contract_on_asymmetric_adjacency() {
    // The PR's acceptance gate: from the second epoch onward zero planning
    // work and zero new buffer allocations; outputs bit-identical to cold
    // execution; backward Âᵀ products run through the mirrored plan —
    // b_rows/c_rows roles exchanged pair-for-pair, volume preserved, no
    // re-covering — including on an asymmetric adjacency.
    let adj = gen::rmat(96, 900, (0.6, 0.25, 0.1), false, 17);
    let cfg = GcnConfig { epochs: 3, log_every: 1, lr: 1.0, ..Default::default() };
    let mut gcn = Gcn::new(
        &adj,
        Strategy::Joint(Solver::Koenig),
        Topology::tsubame4(8),
        true,
        cfg.clone(),
    );
    let warm = gcn.train(&NativeKernel, &NativeDense);
    for (name, a) in [
        ("fwd", gcn.fwd.amortization()),
        ("bwd", gcn.bwd.amortization()),
    ] {
        assert!(a.steady_state(), "{name}: {a:?}");
        assert_eq!(a.total_allocs(), 0, "{name} allocated after plan-time warm-up");
        assert!(
            a.plan_secs.iter().all(|&t| t == 0.0),
            "{name} planned inside execute: {:?}",
            a.plan_secs
        );
    }
    // fwd executes 2 products/epoch, bwd 1.
    assert_eq!(gcn.fwd.amortization().calls(), 3 * 2);
    assert_eq!(gcn.bwd.amortization().calls(), 3);
    // Mirror structure: the backward pair (p→q flow) serves row-based
    // exactly what the forward (q→p flow) served column-based. No cover
    // was re-solved — the role exchange preserves per-pair volume.
    let (fwd, bwd) = (&gcn.fwd.dist().plan, &gcn.bwd.dist().plan);
    assert_eq!(fwd.total_volume(32), bwd.total_volume(32));
    for p in 0..8 {
        for q in 0..8 {
            if p == q {
                continue;
            }
            assert_eq!(bwd.pairs[p][q].c_rows, fwd.pairs[q][p].b_rows, "({p},{q})");
            assert_eq!(bwd.pairs[p][q].b_rows, fwd.pairs[q][p].c_rows, "({p},{q})");
        }
    }
    // Bit-identical to cold per-epoch execution on the same graph.
    let mut cold_gcn = Gcn::new(
        &adj,
        Strategy::Joint(Solver::Koenig),
        Topology::tsubame4(8),
        true,
        cfg,
    );
    let cold = cold_gcn.train_cold(&NativeKernel, &NativeDense);
    assert_eq!(warm.losses.len(), cold.losses.len());
    for ((e1, l1), (e2, l2)) in warm.losses.iter().zip(&cold.losses) {
        assert_eq!((e1, l1.to_bits()), (e2, l2.to_bits()));
    }
}

// ---------------------------------------------- normalize_adj edge cases ----

/// 6-vertex graph exercising every pinned edge case: an isolated vertex,
/// a duplicate diagonal entry, a negative edge, and an explicit zero.
fn edge_case_graph() -> Csr {
    let mut coo = Coo::new(6, 6);
    coo.push(1, 1, 2.0); // duplicate diagonal mass (summed with the +1 loop)
    coo.push(1, 1, 3.0);
    coo.push(2, 3, -4.0); // negative edge: magnitude is used
    coo.push(3, 2, -4.0);
    coo.push(4, 5, 0.0); // explicit zero: stays a structural entry, weight 0
    coo.push(5, 4, 1.0);
    // Vertex 0 is isolated.
    coo.to_csr()
}

#[test]
fn normalize_adj_isolated_vertex_gets_unit_self_loop() {
    let a_hat = normalize_adj(&edge_case_graph());
    a_hat.validate().unwrap();
    // Isolated vertex: exactly one entry, the diagonal, exactly 1.0 — not
    // a huge clamped weight.
    assert_eq!(a_hat.row_indices(0), &[0]);
    assert_eq!(a_hat.row_values(0), &[1.0f32]);
    // Every entry is finite and within [0, 1].
    for r in 0..a_hat.nrows {
        for &v in a_hat.row_values(r) {
            assert!(v.is_finite(), "row {r}: non-finite weight {v}");
            assert!((0.0..=1.0).contains(&v), "row {r}: weight {v} outside [0,1]");
        }
    }
}

#[test]
fn normalize_adj_duplicate_diagonal_is_summed_once() {
    let a_hat = normalize_adj(&edge_case_graph());
    // Vertex 1: unscaled diagonal = 1 (loop) + |2| + |3| = 6 and it is the
    // row's only entry, so deg = 6 and the normalized value is exactly 1.
    assert_eq!(a_hat.row_indices(1), &[1], "duplicates must collapse to one entry");
    assert_eq!(a_hat.row_values(1), &[1.0f32]);
}

#[test]
fn normalize_adj_negative_and_zero_entries() {
    let a_hat = normalize_adj(&edge_case_graph());
    // Negative edge 2↔3: |−4| = 4, deg_2 = deg_3 = 5 ⇒ weight 4/5.
    let k = a_hat.row_indices(2).iter().position(|&c| c == 3).unwrap();
    assert!((a_hat.row_values(2)[k] - 0.8).abs() < 1e-6);
    // Explicit zero 4→5 survives structurally with weight exactly 0.
    let k = a_hat.row_indices(4).iter().position(|&c| c == 5).unwrap();
    assert_eq!(a_hat.row_values(4)[k], 0.0);
}

#[test]
fn training_survives_isolated_vertices() {
    // End-to-end: a graph where a fifth of the vertices are isolated still
    // plans, mirrors, and trains without NaNs.
    let base = gen::rmat(48, 300, (0.5, 0.2, 0.2), true, 19);
    let mut coo = Coo::new(64, 64); // vertices 48..64 isolated
    for r in 0..48 {
        for (k, &c) in base.row_indices(r).iter().enumerate() {
            coo.push(r, c as usize, base.row_values(r)[k]);
        }
    }
    let adj = coo.to_csr();
    let cfg = GcnConfig { epochs: 10, log_every: 1, lr: 1.0, ..Default::default() };
    let mut gcn = Gcn::new(
        &adj,
        Strategy::Joint(Solver::Koenig),
        Topology::tsubame4(4),
        true,
        cfg,
    );
    let r = gcn.train(&NativeKernel, &NativeDense);
    let first = r.losses.first().unwrap().1;
    let last = r.losses.last().unwrap().1;
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first, "isolated vertices broke training: {first} → {last}");
    // The serial oracle agrees that Â rows for isolated vertices are pure
    // self-loops: aggregation leaves their features untouched.
    let a_hat = normalize_adj(&adj);
    let probe = Dense::from_fn(64, 3, |i, j| (i * 3 + j) as f32);
    let agg = a_hat.spmm(&probe);
    for r in 48..64 {
        assert_eq!(agg.row(r), probe.row(r), "isolated row {r} must pass through");
    }
}
