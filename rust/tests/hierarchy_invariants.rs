//! Hierarchy invariants (paper §6, Alg. 1):
//!
//! - every deduplicated B-flow row set crosses the inter-group link
//!   **exactly once** (one stage-I message per (src, dst-group) flow, rows
//!   equal to the deduplicated union);
//! - C-flow pre-aggregation sums equal the flat plan's partials (checked
//!   with integer-exact arithmetic so equality is bitwise);
//! - representative assignment is deterministic.

use shiro::bench::int_matrix;
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::hierarchy;
use shiro::partition::{split_1d, RowPartition};
use shiro::sparse::Csr;
use shiro::topology::Topology;

fn setup(
    n: usize,
    ranks: usize,
    seed: u64,
) -> (Csr, RowPartition, comm::CommPlan, Topology) {
    let a = int_matrix(n, n * 8, seed);
    let part = RowPartition::balanced(n, ranks);
    let blocks = split_1d(&a, &part);
    let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
    let topo = Topology::tsubame4(ranks);
    (a, part, plan, topo)
}

#[test]
fn each_b_flow_crosses_inter_link_exactly_once() {
    for seed in 0..4 {
        let (_, _, plan, topo) = setup(256, 16, seed);
        let sched = hierarchy::build(&plan, &topo);
        // Flow keys are unique per (src, dst_group).
        let mut keys: Vec<(usize, usize)> =
            sched.b_flows.iter().map(|f| (f.src, f.dst_group)).collect();
        keys.sort_unstable();
        let before = keys.len();
        keys.dedup();
        assert_eq!(before, keys.len(), "duplicate B flow (seed {seed})");

        let m = sched.messages();
        // Exactly one inter-group stage-I message per flow, carrying the
        // full deduplicated union — and nothing else crosses for B.
        assert_eq!(m.s1_inter_b.len(), sched.b_flows.len(), "seed {seed}");
        for (flow, msg) in sched.b_flows.iter().zip(&m.s1_inter_b) {
            assert_eq!(msg.src, flow.src);
            assert_eq!(msg.dst, flow.rep);
            assert_eq!(msg.rows, flow.rows.len() as u64);
            assert_ne!(
                topo.group_of(flow.src),
                flow.dst_group,
                "B flow must cross groups"
            );
            // The union is exactly the dedup of its consumers' needs.
            let mut union: Vec<u32> = flow
                .consumers
                .iter()
                .flat_map(|(_, rows)| rows.iter().copied())
                .collect();
            union.sort_unstable();
            union.dedup();
            assert_eq!(union, flow.rows, "seed {seed}: union mismatch");
        }
        // Second-hop B distribution stays intra-group.
        for msg in &m.s2_intra_b {
            assert_eq!(
                topo.group_of(msg.src),
                topo.group_of(msg.dst),
                "stage-II B must not cross groups"
            );
        }
    }
}

#[test]
fn c_flow_preaggregation_sums_equal_flat_partials() {
    let n = 256;
    let ranks = 16;
    let nd = 8;
    // Row strategy guarantees every nonzero cross-group pair contributes a
    // C flow, so the pre-aggregation path is exercised densely.
    let a = int_matrix(n, n * 8, 9);
    let part = RowPartition::balanced(n, ranks);
    let blocks = split_1d(&a, &part);
    let plan = comm::plan(&blocks, &part, Strategy::Row, None);
    let topo = Topology::tsubame4(ranks);
    let sched = hierarchy::build(&plan, &topo);
    let bmat = Dense::from_fn(n, nd, |i, j| ((i * 5 + j * 11) % 9) as f32 - 4.0);
    let b_local = |rank: usize| -> Dense {
        let (r0, r1) = part.range(rank);
        Dense::from_vec(r1 - r0, nd, bmat.data[r0 * nd..r1 * nd].to_vec())
    };
    assert!(!sched.c_flows.is_empty(), "test needs inter-group C flows");
    for flow in &sched.c_flows {
        // Hierarchical path: fold each producer's partial rows into the
        // union-row accumulator (exactly what the rep does in exec).
        let mut agg = Dense::zeros(flow.rows.len(), nd);
        // Flat path: scatter the same partials into a dst-local block.
        let mut flat = Dense::zeros(part.len(flow.dst), nd);
        for (producer, prows) in &flow.producers {
            let pair = &plan.pairs[flow.dst][*producer];
            assert_eq!(&pair.c_rows, prows, "schedule rows drifted from plan");
            let data = pair.a_row_compact.spmm(&b_local(*producer));
            for (i, r) in prows.iter().enumerate() {
                let k = flow.rows.binary_search(r).expect("row in union");
                for (d, s) in agg.row_mut(k).iter_mut().zip(data.row(i)) {
                    *d += s;
                }
            }
            flat.scatter_add_rows(prows, &data);
        }
        for (k, r) in flow.rows.iter().enumerate() {
            assert_eq!(
                agg.row(k),
                flat.row(*r as usize),
                "pre-aggregated row {r} != flat partial sum (dst {})",
                flow.dst
            );
        }
    }
}

#[test]
fn representative_assignment_is_deterministic() {
    for seed in [3u64, 4, 5] {
        let (_, _, plan, topo) = setup(192, 12, seed);
        let s1 = hierarchy::build(&plan, &topo);
        let s2 = hierarchy::build(&plan, &Topology::tsubame4(12));
        let reps_b =
            |s: &hierarchy::HierSchedule| s.b_flows.iter().map(|f| f.rep).collect::<Vec<_>>();
        let reps_c =
            |s: &hierarchy::HierSchedule| s.c_flows.iter().map(|f| f.rep).collect::<Vec<_>>();
        assert_eq!(reps_b(&s1), reps_b(&s2), "seed {seed}");
        assert_eq!(reps_c(&s1), reps_c(&s2), "seed {seed}");
        // Reps live in the group they represent.
        for f in &s1.b_flows {
            assert!(topo.group_members(f.dst_group).contains(&f.rep));
        }
        for f in &s1.c_flows {
            assert!(topo.group_members(f.src_group).contains(&f.rep));
        }
    }
}

#[test]
fn executed_pipeline_consumes_the_simulated_schedule() {
    // The executor's per-rank programs and the simulator's staged message
    // lists are folds of the same `phase_messages` stream: the message
    // *count* and per-tier byte totals the executor measures must match
    // what the schedule prescribes.
    let (a, part, plan, topo) = setup(256, 16, 2);
    let sched = hierarchy::build(&plan, &topo);
    let n_dense = 8;
    let bmat = Dense::from_fn(256, n_dense, |i, j| ((i * 3 + j * 5) % 7) as f32 - 3.0);
    let blocks = split_1d(&a, &part);
    let (_, stats) = shiro::exec::run(
        &part,
        &plan,
        &blocks,
        Some(&sched),
        &topo,
        &bmat,
        &shiro::exec::kernel::NativeKernel,
    );
    // Sender- and receiver-side accounting agree per tier (the satellite
    // fix: bytes used to be counted on the sender only, so rep forwarding
    // could drift from the volume accounting).
    assert_eq!(stats.total_inter_bytes(), stats.total_inter_recv_bytes());
    assert_eq!(stats.total_intra_bytes(), stats.total_intra_recv_bytes());
    // The measured volume matrix tells the same per-tier story.
    let mv = stats.measured_volume();
    assert_eq!(mv.inter_group_total(&topo.group_vec()), stats.total_inter_bytes());
    assert_eq!(
        mv.total(),
        stats.total_inter_bytes() + stats.total_intra_bytes()
    );
    // Executed message count == schedule message count (every StageMsg is
    // one real message; nothing extra crosses the wire).
    let m = sched.messages();
    let sched_msgs = (m.s1_inter_b.len() + m.s1_intra_c.len() + m.s2_inter_c.len()
        + m.s2_intra_b.len()) as u64;
    let sent: u64 = stats.per_rank.iter().map(|r| r.msgs_sent).sum();
    let recv: u64 = stats.per_rank.iter().map(|r| r.msgs_recv).sum();
    assert_eq!(sent, recv);
    assert_eq!(sent, sched_msgs, "executor sent messages the schedule does not know");
    // Payload rows dominate message bytes: dense payload bytes must equal
    // the schedule's row accounting exactly (rows × N × sizeof(f32)); the
    // wire adds 4 bytes per carried row index on top.
    let sched_rows: u64 = [&m.s1_inter_b, &m.s1_intra_c, &m.s2_inter_c, &m.s2_intra_b]
        .iter()
        .flat_map(|v| v.iter())
        .map(|x| x.rows)
        .sum();
    let measured = stats.total_inter_bytes() + stats.total_intra_bytes();
    assert_eq!(
        measured,
        sched_rows * (n_dense as u64 * shiro::comm::SZ_DT + 4),
        "measured bytes drifted from the schedule's volume accounting"
    );
}

#[test]
fn adaptive_plans_respect_the_same_invariants() {
    // The mixed-strategy plan feeds the identical hierarchy machinery.
    let a = int_matrix(256, 2500, 13);
    let part = RowPartition::balanced(256, 16);
    let blocks = split_1d(&a, &part);
    let topo = Topology::tsubame4(16);
    let compiled = shiro::plan::compile(
        &blocks,
        &part,
        &topo,
        &shiro::plan::PlanParams::default(),
    );
    let sched = hierarchy::build(&compiled.plan, &topo);
    let n_dense = 16;
    assert!(
        sched.inter_group_bytes(n_dense)
            <= hierarchy::flat_inter_group_bytes(&compiled.plan, &topo, n_dense)
    );
    let m = sched.messages();
    assert_eq!(m.s1_inter_b.len(), sched.b_flows.len());
    assert_eq!(m.s2_inter_c.len(), sched.c_flows.len());
}
