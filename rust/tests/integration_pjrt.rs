//! Integration: AOT artifacts (JAX/Pallas → HLO text) loaded and executed
//! through PJRT from the Rust side, composed with the distributed executor.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise) and a
//! build with the `pjrt` feature (the offline image has no xla bindings, so
//! the whole suite is compiled out by default).

#![cfg(feature = "pjrt")]

use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::{NativeKernel, SpmmKernel};
use shiro::gnn::{DenseOps, NativeDense, PjrtDense};
use shiro::runtime::{PjrtKernel, Runtime};
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn runtime_loads_manifest() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("load artifacts");
    assert_eq!(rt.platform(), "cpu");
    let names = rt.artifact_names();
    assert!(names.iter().any(|n| n.starts_with("spmm_ell")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("gcn_fwd")), "{names:?}");
}

#[test]
fn pjrt_spmm_matches_native() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    // Shape matching the exported variant (K=512, N=32; rows ≤ 512).
    let a = gen::rmat(512, 6000, (0.55, 0.2, 0.19), false, 3);
    let mut rng = Rng::new(4);
    let b = Dense::random(512, 32, &mut rng);
    let got = rt.spmm(&a, &b).expect("pjrt spmm");
    let want = a.spmm(&b);
    let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
    assert!(err < 1e-3, "rel err {err}");
}

#[test]
fn pjrt_spmm_dense_rows_spill_slabs() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    // A row with ~60 nnz forces multiple KMAX=16 slabs.
    let mut coo = shiro::sparse::Coo::new(512, 512);
    for c in 0..60 {
        coo.push(0, c * 8, 0.5 + c as f32 * 0.01);
    }
    for r in 1..512 {
        coo.push(r, (r * 7) % 512, 1.0);
    }
    let a = coo.to_csr();
    let mut rng = Rng::new(5);
    let b = Dense::random(512, 32, &mut rng);
    let got = rt.spmm(&a, &b).unwrap();
    let want = a.spmm(&b);
    assert!(want.diff_norm(&got) < 1e-2, "{}", want.diff_norm(&got));
}

#[test]
fn distributed_spmm_with_pjrt_kernel() {
    let dir = require_artifacts!();
    let kernel = PjrtKernel::load(&dir).unwrap();
    // 4096 rows over 8 ranks → every local block is 512×512, N=32:
    // all executor SpMM calls hit the AOT kernel (rows ≤ 512, K = 512).
    let a = gen::rmat(4096, 40_000, (0.55, 0.2, 0.19), true, 6);
    let topo = Topology::tsubame4(8);
    let d = PlanSpec::new(topo).strategy(Strategy::Joint(Solver::Koenig)).plan(&a);
    let mut rng = Rng::new(7);
    let b = Dense::random(4096, 32, &mut rng);
    let (got, _) = d
        .execute(&ExecRequest::spmm(&b).kernel(&kernel))
        .expect("thread-backend SpMM")
        .into_dense();
    let want = a.spmm(&b);
    let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
    assert!(err < 1e-3, "rel err {err}");
    assert_eq!(
        kernel.fallbacks.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "all local SpMMs must go through the AOT kernel"
    );
}

#[test]
fn gcn_dense_artifacts_match_native() {
    let dir = require_artifacts!();
    let kernel = PjrtKernel::load(&dir).unwrap();
    let pjrt = PjrtDense { kernel: &kernel, chunk: 512 };
    let mut rng = Rng::new(8);
    let h_agg = Dense::random(1024, 32, &mut rng);
    let w = Dense::random(32, 32, &mut rng);
    let (z_p, h_p) = pjrt.fwd(&h_agg, &w);
    let (z_n, h_n) = NativeDense.fwd(&h_agg, &w);
    assert!(z_n.diff_norm(&z_p) < 1e-2);
    assert!(h_n.diff_norm(&h_p) < 1e-2);

    let dh = Dense::random(1024, 32, &mut rng);
    let (da_p, dw_p) = pjrt.bwd(&h_agg, &w, &z_p, &dh);
    let (da_n, dw_n) = NativeDense.bwd(&h_agg, &w, &z_n, &dh);
    assert!(da_n.diff_norm(&da_p) < 1e-2);
    assert!(dw_n.diff_norm(&dw_p) < 1e-2);

    let target = Dense::random(1024, 32, &mut rng);
    let (l_p, g_p) = pjrt.mse(&h_p, &target);
    let (l_n, g_n) = NativeDense.mse(&h_n, &target);
    assert!((l_p - l_n).abs() < 1e-4, "{l_p} vs {l_n}");
    assert!(g_n.diff_norm(&g_p) < 1e-4);
}

#[test]
fn fused_gcn_kernel_matches_composition() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    // Sparse block with ≤16 nnz per row (one ELL slab).
    let a = gen::erdos_renyi(512, 512, 3000, 11);
    let mut rng = Rng::new(12);
    let b = Dense::random(512, 32, &mut rng);
    let w = Dense::random(32, 32, &mut rng);
    let (z, h) = rt.gcn_fused(&a, &b, &w).expect("fused artifact");
    // Oracle: spmm then matmul then relu.
    let agg = a.spmm(&b);
    let z_ref = agg.matmul(&w);
    let mut h_ref = z_ref.clone();
    for v in h_ref.data.iter_mut() {
        *v = v.max(0.0);
    }
    assert!(z_ref.diff_norm(&z) < 1e-2, "{}", z_ref.diff_norm(&z));
    assert!(h_ref.diff_norm(&h) < 1e-2);
}

#[test]
fn native_kernel_used_as_fallback_for_odd_shapes() {
    let dir = require_artifacts!();
    let kernel = PjrtKernel::load(&dir).unwrap();
    // 100×100, N=7: no artifact — must silently fall back and stay correct.
    let a = gen::erdos_renyi(100, 100, 500, 9);
    let mut rng = Rng::new(10);
    let b = Dense::random(100, 7, &mut rng);
    let got = kernel.spmm(&a, &b);
    assert!(a.spmm(&b).diff_norm(&got) < 1e-4);
    assert!(kernel.fallbacks.load(std::sync::atomic::Ordering::Relaxed) > 0);
    // And the native kernel trait object names stay distinct.
    assert_eq!(NativeKernel.name(), "native");
    assert_eq!(kernel.name(), "pjrt");
}
