//! Integration: distributed SpMM across the full dataset registry — every
//! strategy × flat/hierarchical routing, executed on real in-process ranks
//! and verified against the serial reference; plus failure-injection tests
//! for the planning edge cases.

use shiro::bench::int_matrix;
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::exec::ExecOpts;
use shiro::partition::Partitioner;
use shiro::sparse::{datasets::DATASETS, gen, Coo, Csr};
use shiro::spmm::{DistSpmm, ExecRequest, PlanSpec, Replicate};
use shiro::topology::Topology;
use shiro::util::rng::Rng;

fn plan(a: &Csr, strategy: Strategy, topo: Topology, hier: bool) -> DistSpmm {
    PlanSpec::new(topo).strategy(strategy).hierarchical(hier).plan(a)
}

fn spmm(d: &DistSpmm, b: &Dense, opts: &ExecOpts) -> Dense {
    d.execute(&ExecRequest::spmm(b).kernel(&NativeKernel).opts(*opts))
        .expect("thread-backend SpMM")
        .into_dense()
        .0
}

fn check(d: &DistSpmm, a: &Csr, n_dense: usize, label: &str) {
    let mut rng = Rng::new(99);
    let b = Dense::random(a.nrows, n_dense, &mut rng);
    let got = spmm(d, &b, &ExecOpts::default());
    let want = a.spmm(&b);
    let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
    assert!(err < 1e-3, "{label}: rel err {err}");
}

#[test]
fn all_datasets_joint_hier_exact() {
    for spec in DATASETS {
        let a = spec.generate(0.005);
        let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(8), true);
        check(&d, &a, 8, spec.name);
    }
}

#[test]
fn all_strategies_on_web_pattern() {
    let a = gen::powerlaw(512, 6000, 1.4, 1);
    for strategy in [
        Strategy::Block,
        Strategy::Column,
        Strategy::Row,
        Strategy::Joint(Solver::Koenig),
        Strategy::Joint(Solver::Dinic),
        Strategy::Joint(Solver::Greedy),
    ] {
        for hier in [false, true] {
            if hier && strategy == Strategy::Block {
                continue; // block mode is defined flat-only in the paper
            }
            let d = plan(&a, strategy, Topology::tsubame4(8), hier);
            check(&d, &a, 16, &format!("{strategy:?} hier={hier}"));
        }
    }
}

#[test]
fn aurora_topology_exact() {
    let a = gen::rmat(512, 6000, (0.5, 0.22, 0.18), false, 2);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::aurora(24), true);
    check(&d, &a, 8, "aurora-24");
}

#[test]
fn ranks_not_multiple_of_group() {
    // 10 ranks on groups of 4 → ragged last group.
    let a = gen::rmat(512, 5000, (0.5, 0.2, 0.2), false, 3);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(10), true);
    check(&d, &a, 4, "ragged-groups");
}

#[test]
fn more_ranks_than_nonzero_blocks() {
    // Block-diagonal-ish matrix: most off-diagonal blocks empty.
    let mut coo = Coo::new(256, 256);
    for i in 0..256 {
        coo.push(i, i, 2.0);
        if i >= 1 {
            coo.push(i, i - 1, 1.0);
        }
    }
    let a = coo.to_csr();
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(16), true);
    check(&d, &a, 8, "tridiagonal");
}

#[test]
fn single_column_b() {
    // N = 1 (SpMV degenerate case).
    let a = gen::erdos_renyi(300, 300, 2000, 5);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(6), true);
    check(&d, &a, 1, "spmv");
}

#[test]
fn hot_row_and_hot_column() {
    // Failure-injection-ish adversarial pattern: one full row + one full
    // column (maximal skew both ways).
    let mut coo = Coo::new(128, 128);
    for j in 0..128 {
        coo.push(7, j, 1.0);
        coo.push(j, 9, 1.0);
    }
    let a = coo.to_csr();
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(8), true);
    // Joint plan should be tiny: the hot row + hot column form a 2-vertex
    // cover per block.
    let vol = d.plan.total_volume(1) / 4;
    assert!(vol <= 4 * 8 * 8, "cover should collapse hot cross: {vol} rows");
    check(&d, &a, 8, "hot-cross");
}

#[test]
fn pipeline_determinism_across_worker_threads() {
    // Satellite: run the overlapped executor 8× across 1/2/4/8 worker
    // threads — every run must be bit-identical to the serial reference
    // (exact-integer input makes that a legitimate bitwise oracle).
    let a = int_matrix(256, 2048, 42);
    let b = Dense::from_fn(256, 8, |i, j| ((i * 7 + j * 3) % 9) as f32 - 4.0);
    let want = a.spmm(&b);
    for hier in [true, false] {
        let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(8), hier);
        for workers in [1usize, 2, 4, 8] {
            for rep in 0..2 {
                let opts = ExecOpts { workers, ..ExecOpts::default() };
                let got = spmm(&d, &b, &opts);
                assert_eq!(
                    got.data, want.data,
                    "hier={hier} workers={workers} rep={rep}: bits differ from serial"
                );
            }
        }
    }
}

#[test]
fn pipeline_determinism_on_arbitrary_floats() {
    // On arbitrary float inputs the serial reference is not a bitwise
    // oracle (different summation order), but the executor must agree with
    // *itself*: any worker count, overlap mode, or tile height — same bits.
    let a = gen::powerlaw(512, 6000, 1.4, 23);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(8), true);
    let mut rng = Rng::new(31);
    let b = Dense::random(512, 16, &mut rng);
    let reference = spmm(&d, &b, &ExecOpts::sequential());
    for workers in [1usize, 2, 4, 8] {
        for tile_rows in [0usize, 13] {
            let opts = ExecOpts { overlap: true, workers, tile_rows };
            let got = spmm(&d, &b, &opts);
            assert_eq!(
                got.data, reference.data,
                "workers={workers} tile={tile_rows}: nondeterministic bits"
            );
        }
    }
    // And the answer is still right.
    let want = a.spmm(&b);
    let err = want.diff_norm(&reference) / (want.max_abs() as f64 + 1e-30);
    assert!(err < 1e-3);
}

#[test]
fn determinism_across_partitioners() {
    // Satellite: on integer-exact inputs the executed result must be
    // bit-identical to the serial reference for all three partitioners ×
    // overlap on/off × 1/2/4/8 worker threads — load-aware boundaries must
    // not change what is computed, only where.
    let a = int_matrix(256, 2048, 77);
    let b = Dense::from_fn(256, 8, |i, j| ((i * 5 + j * 11) % 7) as f32 - 3.0);
    let want = a.spmm(&b);
    for partitioner in Partitioner::ALL {
        let d = PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .partitioner(partitioner)
            .plan(&a);
        for overlap in [true, false] {
            for workers in [1usize, 2, 4, 8] {
                let base = if overlap { ExecOpts::default() } else { ExecOpts::sequential() };
                let opts = ExecOpts { workers, ..base };
                let got = spmm(&d, &b, &opts);
                assert_eq!(
                    got.data,
                    want.data,
                    "{} overlap={overlap} workers={workers}: bits differ from serial",
                    partitioner.name()
                );
            }
        }
    }
}

#[test]
fn replicated_bitwise_to_serial_across_strategies() {
    // The 1.5D engine (DESIGN.md §13) on integer-exact inputs: every
    // replication factor × strategy (Adaptive runs the per-pair compiler
    // at group granularity) × overlap mode must reproduce the serial
    // reference bit for bit — which also pins c>1 to the flat c=1 engine,
    // since `determinism_across_partitioners` pins that to serial.
    let a = int_matrix(256, 2048, 91);
    let b = Dense::from_fn(256, 8, |i, j| ((i * 5 + j * 7) % 9) as f32 - 4.0);
    let want = a.spmm(&b);
    for strategy in [
        Strategy::Column,
        Strategy::Row,
        Strategy::Joint(Solver::Koenig),
        Strategy::Adaptive,
    ] {
        for c in [2usize, 4] {
            let d = PlanSpec::new(Topology::tsubame4(8))
                .strategy(strategy)
                .n_dense(8)
                .replicate(Replicate::Factor(c))
                .plan(&a);
            let rep = d.rep.as_ref().expect("c>1 plan must carry a RepSchedule");
            assert_eq!(rep.map.c, c);
            assert_eq!(rep.validate(&d.plan), Ok(()), "{strategy:?} c={c}");
            assert!(d.sched.is_none(), "replicated plans own their two-level fold");
            for overlap in [true, false] {
                let opts = if overlap { ExecOpts::default() } else { ExecOpts::sequential() };
                let got = spmm(&d, &b, &opts);
                assert_eq!(
                    got.data, want.data,
                    "{strategy:?} c={c} overlap={overlap}: bits differ from serial"
                );
            }
        }
    }
}

#[test]
fn replicated_proc_matches_thread_bitwise() {
    // The proc backend ships the group-level problem plus the RepSchedule
    // over the wire (v5 blobs) and runs the same two-level fold per
    // worker process, so C and the measured volume matrix must match the
    // thread backend exactly.
    use shiro::runtime::multiproc::ProcOpts;
    use shiro::spmm::Backend;
    use std::time::Duration;
    let a = int_matrix(192, 1800, 17);
    let b = Dense::from_fn(192, 6, |i, j| ((i * 3 + j * 11) % 7) as f32 - 3.0);
    let want = a.spmm(&b);
    for c in [2usize, 4] {
        let d = PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .partitioner(Partitioner::NnzBalanced)
            .n_dense(6)
            .replicate(Replicate::Factor(c))
            .plan(&a);
        let (c_thread, s_thread) =
            d.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
        let popts = ProcOpts {
            timeout: Duration::from_secs(60),
            worker_exe: Some(env!("CARGO_BIN_EXE_shiro").into()),
            fault: None,
            pool: None,
        };
        let (c_proc, s_proc) = d
            .execute(&ExecRequest::spmm(&b).backend(Backend::Proc(popts)))
            .unwrap_or_else(|f| panic!("c={c}: proc backend failed: {f}"))
            .into_dense();
        assert_eq!(c_thread.data, want.data, "c={c}: thread bits differ from serial");
        assert_eq!(c_proc.data, c_thread.data, "c={c}: proc bits differ from thread");
        assert_eq!(
            s_thread.measured_volume(),
            s_proc.measured_volume(),
            "c={c}: measured volume differs across backends"
        );
    }
}

#[test]
fn replicated_rejects_sddmm_family() {
    // Replication wiring exists for SpMM only; the SDDMM family must
    // surface a structured Unsupported error, not a wrong answer.
    let a = int_matrix(128, 1200, 5);
    let d = PlanSpec::new(Topology::tsubame4(8))
        .replicate(Replicate::Factor(2))
        .plan(&a);
    let x = Dense::from_fn(128, 4, |i, j| ((i + j) % 5) as f32);
    let y = Dense::from_fn(128, 4, |i, j| ((i * 2 + j) % 5) as f32);
    for req in [ExecRequest::sddmm(&x, &y), ExecRequest::fused(&x, &y)] {
        match d.execute(&req) {
            Err(shiro::spmm::ExecError::Unsupported(_)) => {}
            other => panic!("expected Unsupported, got {:?}", other.is_ok()),
        }
    }
}

#[test]
fn prep_time_recorded() {
    let a = gen::rmat(1024, 20_000, (0.55, 0.2, 0.19), false, 6);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(16), true);
    assert!(d.prep_secs > 0.0);
    assert!(d.sched.is_some());
}
