//! Differential multiprocess suite: the `--backend proc` control plane
//! pinned **bitwise** against the in-process thread executor on the same
//! frozen plans. Both backends run the identical serialized step program
//! and fold partial C blocks in the canonical (origin, row) order, so C
//! must match bit for bit — and the measured volume matrices (decoded
//! from worker `DONE` frames) must agree too. The kill test aborts a
//! worker mid-run and asserts the parent reports a structured
//! [`RankFailure`] within the deadline instead of hanging.
//!
//! Worker processes are this crate's own binary (re-entered through
//! `maybe_run_worker`), located via `CARGO_BIN_EXE_shiro`.

use std::time::{Duration, Instant};

use shiro::bench::int_matrix;
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::ExecOpts;
use shiro::partition::Partitioner;
use shiro::runtime::multiproc::{FailureCause, FaultPlan, PoolHandle, ProcOpts};
use shiro::sparse::Csr;
use shiro::spmm::{Backend, DistSpmm, ExecError, ExecRequest, PlanSpec};
use shiro::topology::Topology;

fn popts() -> ProcOpts {
    ProcOpts {
        timeout: Duration::from_secs(60),
        worker_exe: Some(env!("CARGO_BIN_EXE_shiro").into()),
        fault: None,
        pool: None,
    }
}

fn proc_backend() -> Backend {
    Backend::Proc(popts())
}

fn plan(a: &Csr, strategy: Strategy, ranks: usize, hier: bool) -> DistSpmm {
    PlanSpec::new(Topology::tsubame4(ranks)).strategy(strategy).hierarchical(hier).plan(a)
}

fn int_xy(n: usize, k: usize) -> (Dense, Dense) {
    let x = Dense::from_fn(n, k, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
    let y = Dense::from_fn(n, k, |i, j| ((i * 3 + j * 11) % 7) as f32 - 3.0);
    (x, y)
}

#[test]
fn proc_matches_thread_bitwise_across_strategies() {
    let a = int_matrix(128, 1500, 42);
    let b = Dense::from_fn(128, 8, |i, j| ((i * 7 + j * 5) % 9) as f32 - 4.0);
    for strategy in
        [Strategy::Block, Strategy::Column, Strategy::Row, Strategy::Joint(Solver::Koenig)]
    {
        // Block mode is defined flat-only in the paper; the rest route
        // hierarchically so the proc backend carries CAgg flows too.
        let hier = strategy != Strategy::Block;
        let d = plan(&a, strategy, 4, hier);
        let (c_thread, s_thread) =
            d.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
        let (c_proc, s_proc) = d
            .execute(&ExecRequest::spmm(&b).backend(proc_backend()))
            .unwrap_or_else(|f| panic!("{strategy:?}: proc backend failed: {f}"))
            .into_dense();
        assert_eq!(c_thread.data, c_proc.data, "{strategy:?}: C bits differ across backends");
        assert_eq!(
            s_thread.measured_volume(),
            s_proc.measured_volume(),
            "{strategy:?}: measured volume differs across backends"
        );
    }
}

#[test]
fn proc_matches_thread_across_partitioners_and_rank_counts() {
    let a = int_matrix(160, 1800, 7);
    let b = Dense::from_fn(160, 4, |i, j| ((i * 3 + j * 13) % 11) as f32 - 5.0);
    for partitioner in Partitioner::ALL {
        for ranks in [1usize, 2, 4] {
            let d = PlanSpec::new(Topology::tsubame4(ranks))
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(ranks > 1)
                .partitioner(partitioner)
                .plan(&a);
            // Overlap on (pipelined) and off (phase-ordered): arrival order
            // differs, but the canonical fold keeps both bitwise-stable.
            for opts in [ExecOpts::default(), ExecOpts::sequential()] {
                let (c_thread, _) = d
                    .execute(&ExecRequest::spmm(&b).opts(opts))
                    .expect("thread backend")
                    .into_dense();
                let (c_proc, _) = d
                    .execute(&ExecRequest::spmm(&b).opts(opts).backend(proc_backend()))
                    .unwrap_or_else(|f| {
                        panic!("{}/{ranks} ranks: proc failed: {f}", partitioner.name())
                    })
                    .into_dense();
                assert_eq!(
                    c_thread.data,
                    c_proc.data,
                    "{}/{ranks} ranks/{opts:?}: C bits differ",
                    partitioner.name()
                );
            }
        }
    }
}

#[test]
fn proc_matches_thread_across_groups() {
    // Eight ranks on tsubame4 span two groups: inter-group B flows and
    // hierarchical C aggregation all cross the wire.
    let a = int_matrix(192, 2200, 19);
    let b = Dense::from_fn(192, 8, |i, j| ((i * 11 + j * 7) % 9) as f32 - 4.0);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 8, true);
    let (c_thread, s_thread) =
        d.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
    let (c_proc, s_proc) = d
        .execute(&ExecRequest::spmm(&b).backend(proc_backend()))
        .expect("proc backend failed")
        .into_dense();
    assert_eq!(c_thread.data, c_proc.data, "inter-group C bits differ");
    assert_eq!(s_thread.measured_volume(), s_proc.measured_volume());
    assert!(s_proc.measured_volume().total() > 0, "degenerate: nothing crossed the wire");
}

#[test]
fn fused_proc_matches_thread_bitwise() {
    // Fused SDDMM→SpMM ships X replicas as Msg::X frames; pin those too.
    let a = int_matrix(128, 1400, 77);
    let (x, y) = int_xy(128, 4);
    for hier in [false, true] {
        let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, hier);
        let (c_thread, _) =
            d.execute(&ExecRequest::fused(&x, &y)).expect("thread backend").into_dense();
        let (c_proc, _) = d
            .execute(&ExecRequest::fused(&x, &y).backend(proc_backend()))
            .unwrap_or_else(|f| panic!("hier={hier}: fused proc failed: {f}"))
            .into_dense();
        assert_eq!(c_thread.data, c_proc.data, "hier={hier}: fused C bits differ");
    }
}

#[test]
fn sddmm_proc_matches_thread_bitwise() {
    // SDDMM over the proc backend ships edge values home in the op-gated
    // `SddmmVals` DONE payload; pin pattern, values, and measured volume
    // bitwise against the thread executor across routing modes and rank
    // counts (4 ranks = one group, 8 = two groups on tsubame4).
    let a = int_matrix(128, 1400, 55);
    let (x, y) = int_xy(128, 4);
    for (ranks, hier) in [(4usize, false), (4, true), (8, true)] {
        let d = plan(&a, Strategy::Joint(Solver::Koenig), ranks, hier);
        let (e_thread, s_thread) =
            d.execute(&ExecRequest::sddmm(&x, &y)).expect("thread backend").into_sparse();
        let (e_proc, s_proc) = d
            .execute(&ExecRequest::sddmm(&x, &y).backend(proc_backend()))
            .unwrap_or_else(|f| panic!("{ranks} ranks hier={hier}: SDDMM proc failed: {f}"))
            .into_sparse();
        assert_eq!(e_thread, e_proc, "{ranks} ranks hier={hier}: SDDMM bits differ");
        assert_eq!(e_proc, a.sddmm(&x, &y), "{ranks} ranks hier={hier}: oracle mismatch");
        assert_eq!(
            s_thread.measured_volume(),
            s_proc.measured_volume(),
            "{ranks} ranks hier={hier}: measured volume differs across backends"
        );
    }
}

fn pooled_backend(pool: &PoolHandle) -> Backend {
    Backend::Proc(ProcOpts { pool: Some(pool.clone()), ..popts() })
}

#[test]
fn warm_pool_matches_cold_bitwise_and_never_respawns() {
    // The tentpole contract: request 1 spawns the fleet, every later
    // request reuses the live connections (zero new spawns), and warm
    // results stay bitwise identical to both the cold pooled run and the
    // spawn-per-request (ephemeral pool) path.
    let a = int_matrix(128, 1500, 42);
    let b = Dense::from_fn(128, 8, |i, j| ((i * 7 + j * 5) % 9) as f32 - 4.0);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let (c_ephemeral, _) = d
        .execute(&ExecRequest::spmm(&b).backend(proc_backend()))
        .expect("ephemeral proc backend")
        .into_dense();

    let pool = PoolHandle::new();
    let (c_cold, _) = d
        .execute(&ExecRequest::spmm(&b).backend(pooled_backend(&pool)))
        .expect("cold pooled run")
        .into_dense();
    assert_eq!(c_cold.data, c_ephemeral.data, "pooled C bits differ from ephemeral");
    let s = pool.stats();
    assert_eq!(s.spawns, 4, "cold request must spawn exactly nranks workers");
    assert_eq!(s.reuses, 0);

    const WARM: usize = 3;
    for i in 0..WARM {
        let (c_warm, _) = d
            .execute(&ExecRequest::spmm(&b).backend(pooled_backend(&pool)))
            .unwrap_or_else(|f| panic!("warm request {i} failed: {f}"))
            .into_dense();
        assert_eq!(c_warm.data, c_cold.data, "warm request {i}: C bits differ from cold");
    }
    let s = pool.stats();
    assert_eq!(s.spawns, 4, "warm requests must not spawn: fleet is persistent");
    assert_eq!(s.reuses, WARM as u64, "every warm request is one reuse");
    assert_eq!(s.readmissions, 0, "nothing died, nothing to re-admit");
}

#[test]
fn warm_pool_survives_op_and_plan_changes() {
    // Delta-vs-full shipping is correctness-invariant: changing the kernel
    // op and then the frozen plan on one warm fleet forces fingerprint
    // misses (full JOB reships), while repeats hit the worker-side plan
    // cache — all on the same live connections, all bitwise vs thread.
    let a = int_matrix(128, 1400, 77);
    let b = Dense::from_fn(128, 4, |i, j| ((i * 3 + j * 13) % 11) as f32 - 5.0);
    let (x, y) = int_xy(128, 4);
    let pool = PoolHandle::new();

    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    for _round in 0..2 {
        let (c_thread, _) =
            d.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
        let (c_proc, _) = d
            .execute(&ExecRequest::spmm(&b).backend(pooled_backend(&pool)))
            .expect("pooled spmm")
            .into_dense();
        assert_eq!(c_proc.data, c_thread.data, "pooled spmm bits differ");

        let (e_thread, _) =
            d.execute(&ExecRequest::sddmm(&x, &y)).expect("thread backend").into_sparse();
        let (e_proc, _) = d
            .execute(&ExecRequest::sddmm(&x, &y).backend(pooled_backend(&pool)))
            .expect("pooled sddmm")
            .into_sparse();
        assert_eq!(e_proc, e_thread, "pooled sddmm bits differ");
    }

    // A different frozen plan (new strategy) on the same warm fleet.
    let d2 = plan(&a, Strategy::Column, 4, true);
    let (c_thread, _) = d2.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
    let (c_proc, _) = d2
        .execute(&ExecRequest::spmm(&b).backend(pooled_backend(&pool)))
        .expect("pooled spmm on new plan")
        .into_dense();
    assert_eq!(c_proc.data, c_thread.data, "pooled spmm on a new plan: bits differ");

    let s = pool.stats();
    assert_eq!(s.spawns, 4, "op/plan changes must never respawn the fleet");
    assert_eq!(s.reuses, 5, "five warm requests after the cold one");
}

#[test]
fn pool_rebuilds_when_the_rank_count_changes() {
    // A handle carries one fleet shape; asking for a different nranks
    // tears the old fleet down and spawns the new shape (counted as
    // fresh spawns), still bitwise against the thread oracle.
    let a = int_matrix(160, 1800, 7);
    let b = Dense::from_fn(160, 4, |i, j| ((i + 2 * j) % 7) as f32 - 3.0);
    let pool = PoolHandle::new();
    for ranks in [2usize, 4] {
        let d = plan(&a, Strategy::Joint(Solver::Koenig), ranks, ranks > 2);
        let (c_thread, _) =
            d.execute(&ExecRequest::spmm(&b)).expect("thread backend").into_dense();
        let (c_proc, _) = d
            .execute(&ExecRequest::spmm(&b).backend(pooled_backend(&pool)))
            .unwrap_or_else(|f| panic!("{ranks} ranks: pooled run failed: {f}"))
            .into_dense();
        assert_eq!(c_proc.data, c_thread.data, "{ranks} ranks: bits differ");
        // A rebuild replaces the fleet (and its counters): each shape's
        // first request reads as a fresh cold start on the handle.
        let s = pool.stats();
        assert_eq!(s.spawns, ranks as u64, "{ranks} ranks: fleet shape mismatch");
        assert_eq!(s.reuses, 0, "{ranks} ranks: cold start after rebuild");
    }
}

#[test]
fn worker_kill_reports_rank_failure() {
    // Abort rank 1 right after it decodes its job: the parent must surface
    // a structured RankFailure for that rank well before the timeout —
    // never hang, never exit(1) through a panic in a routing thread.
    let a = int_matrix(128, 1500, 3);
    let b = Dense::from_fn(128, 4, |i, j| ((i + j) % 5) as f32);
    let d = plan(&a, Strategy::Joint(Solver::Koenig), 4, true);
    let popts = ProcOpts {
        timeout: Duration::from_secs(10),
        fault: Some(FaultPlan::post_decode(1)),
        ..popts()
    };
    let t0 = Instant::now();
    let err = d
        .execute(&ExecRequest::spmm(&b).backend(Backend::Proc(popts)))
        .expect_err("run with a killed worker must fail");
    let wall = t0.elapsed();
    let err = match err {
        ExecError::Rank(f) => f,
        other => panic!("expected a structured RankFailure, got {other}"),
    };
    assert_eq!(err.rank, 1, "failure must be attributed to the killed rank: {err}");
    assert!(
        matches!(
            err.cause,
            FailureCause::Disconnected(_)
                | FailureCause::HeartbeatTimeout(_)
                | FailureCause::Worker(_)
        ),
        "unexpected cause: {err}"
    );
    assert!(wall < Duration::from_secs(30), "failure took {wall:?} — parent nearly hung");
}
