//! Partition-hardened suite (PR 3): the load-aware [`Partitioner`]
//! subsystem end-to-end — straggler reduction on skewed inputs, cost-model
//! coupling, and non-uniform partitions flowing through
//! plan → hierarchy → exec → sim with the same invariants the balanced
//! seed enjoyed.

use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::{self, kernel::NativeKernel, ExecOpts};
use shiro::hierarchy;
use shiro::metrics::load_imbalance;
use shiro::partition::{
    max_rank_nnz, rank_nnz, refine_objective, split_1d, Partitioner, RowPartition,
};
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::rng::Rng;

/// The skewed pattern class the load-aware partitioners exist for: rmat
/// with a strong top-left bias concentrates nonzeros in low row indices,
/// so equal-row-count splitting is maximally unfair.
fn skewed(seed: u64) -> shiro::sparse::Csr {
    gen::rmat(512, 8000, (0.6, 0.18, 0.18), false, seed)
}

#[test]
fn nnz_balanced_reduces_straggler_on_skew() {
    for seed in [1u64, 2, 3] {
        let a = skewed(seed);
        let bal = RowPartition::balanced(a.nrows, 8);
        let nnz = RowPartition::nnz_balanced(&a, 8);
        let bal_max = max_rank_nnz(&a, &bal);
        let nnz_max = max_rank_nnz(&a, &nnz);
        assert!(
            nnz_max < bal_max,
            "seed {seed}: nnz-balanced {nnz_max} !< balanced {bal_max}"
        );
        assert!(
            load_imbalance(&rank_nnz(&a, &nnz)) <= load_imbalance(&rank_nnz(&a, &bal)),
            "seed {seed}: imbalance factor did not shrink"
        );
    }
}

#[test]
fn cost_refined_couples_to_the_plan_cost_model() {
    let a = skewed(4);
    let topo = Topology::tsubame4(8);
    let n_dense = 32;
    let nnz = RowPartition::nnz_balanced(&a, 8);
    let refined = Partitioner::CostRefined.partition(&a, 8, &topo, n_dense);
    // The greedy search only accepts strictly improving moves, so the
    // refined partition's objective never exceeds its starting point.
    assert!(
        refine_objective(&a, &refined, &topo, n_dense)
            <= refine_objective(&a, &nnz, &topo, n_dense) + 1e-15
    );
    // And the objective it optimizes is exactly comm cost + straggler
    // compute, so its max-rank nnz stays well under the balanced split's.
    let bal = RowPartition::balanced(a.nrows, 8);
    assert!(max_rank_nnz(&a, &refined) <= max_rank_nnz(&a, &bal));
}

#[test]
fn every_partitioner_every_strategy_exact() {
    let a = skewed(5);
    let mut rng = Rng::new(2);
    let b = Dense::random(a.nrows, 8, &mut rng);
    let want = a.spmm(&b);
    for partitioner in Partitioner::ALL {
        for strategy in [
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
            Strategy::Adaptive,
        ] {
            let d = PlanSpec::new(Topology::tsubame4(8))
                .strategy(strategy)
                .partitioner(partitioner)
                .plan(&a);
            let (got, _) = d
                .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
            assert!(
                err < 1e-3,
                "{} × {:?}: rel err {err}",
                partitioner.name(),
                strategy
            );
        }
    }
}

#[test]
fn hierarchy_invariants_hold_on_nonuniform_partition() {
    let a = skewed(6);
    let part = RowPartition::nnz_balanced(&a, 16);
    let blocks = split_1d(&a, &part);
    let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
    let topo = Topology::tsubame4(16);
    let sched = hierarchy::build(&plan, &topo);
    let n_dense = 16;
    // Dedup still only reduces inter-group traffic under uneven blocks.
    assert!(
        sched.inter_group_bytes(n_dense)
            <= hierarchy::flat_inter_group_bytes(&plan, &topo, n_dense)
    );
    // Consumer row lists remain subsets of each flow's union.
    for f in &sched.b_flows {
        for (_, rows) in &f.consumers {
            for r in rows {
                assert!(f.rows.binary_search(r).is_ok());
            }
        }
    }
    for f in &sched.c_flows {
        for (_, rows) in &f.producers {
            for r in rows {
                assert!(f.rows.binary_search(r).is_ok());
            }
        }
    }
}

#[test]
fn byte_accounting_agrees_on_nonuniform_partition() {
    // Sender- and receiver-side per-tier totals must still match when
    // block heights differ per rank (the accounting never assumed uniform
    // widths, and this pins that down).
    let a = skewed(7);
    let part = RowPartition::nnz_balanced(&a, 8);
    let blocks = split_1d(&a, &part);
    let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
    let topo = Topology::tsubame4(8);
    let sched = hierarchy::build(&plan, &topo);
    let mut rng = Rng::new(3);
    let b = Dense::random(a.nrows, 8, &mut rng);
    for opts in [ExecOpts::default(), ExecOpts::sequential()] {
        let (_, stats) = exec::run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &opts,
        );
        assert_eq!(stats.total_inter_bytes(), stats.total_inter_recv_bytes());
        assert_eq!(stats.total_intra_bytes(), stats.total_intra_recv_bytes());
    }
}

#[test]
fn simulation_consumes_nonuniform_partitions() {
    let a = skewed(8);
    for partitioner in Partitioner::ALL {
        let d = PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .partitioner(partitioner)
            .plan(&a);
        let rep = d.simulate(16);
        assert!(rep.total > 0.0, "{}", partitioner.name());
        assert_eq!(rep.per_stage.len(), 4);
        // Flat sim path too.
        let flat = PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .partitioner(partitioner)
            .flat()
            .plan(&a);
        assert_eq!(flat.simulate(16).per_stage.len(), 3);
    }
}

#[test]
fn partitioned_plans_share_the_cache_correctly() {
    // End-to-end companion of the plan-cache key regression: one cache,
    // two partitioners — two distinct entries, each hit on re-lookup.
    let a = skewed(9);
    let topo = Topology::tsubame4(8);
    let params = shiro::plan::PlanParams::default();
    let mut cache = shiro::plan::cache::PlanCache::in_memory();
    for partitioner in [Partitioner::Balanced, Partitioner::NnzBalanced] {
        let part = partitioner.partition(&a, 8, &topo, params.n_dense);
        let blocks = split_1d(&a, &part);
        let (_, hit) = cache.get_or_compile(&blocks, &part, &topo, &params);
        assert!(!hit, "{} first lookup must miss", partitioner.name());
        let (_, hit) = cache.get_or_compile(&blocks, &part, &topo, &params);
        assert!(hit, "{} second lookup must hit", partitioner.name());
    }
    assert_eq!((cache.hits, cache.misses), (2, 2));
}
