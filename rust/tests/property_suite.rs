//! Property-based tests on coordinator invariants (in-tree harness,
//! DESIGN.md §1): routing correctness, cover optimality bounds, schedule
//! conservation laws, and end-to-end numerics over randomized matrices,
//! partitions, and topologies.

use shiro::comm::{self, Strategy};
use shiro::cover::{self, Solver, Weights};
use shiro::dense::Dense;
use shiro::exec::{self, kernel::NativeKernel};
use shiro::hierarchy;
use shiro::partition::{
    assemble_1d, rank_nnz, recover_partition, refine_objective, split_1d, Partitioner,
    RowPartition,
};
use shiro::sparse::{gen, Csr};
use shiro::spmm::{ExecRequest, PlanSpec, Replicate};
use shiro::topology::Topology;
use shiro::util::proptest::{forall, Gen};

/// Random sparse matrix drawn from one of the generator families.
fn random_matrix(g: &mut Gen) -> Csr {
    let n = 1 << g.usize_in(5, 9); // 32..256
    let family = g.usize_in(0, 4);
    let nnz = n * g.usize_in(2, 12);
    let seed = g.rng().next_u64();
    match family {
        0 => gen::rmat(n, nnz, (0.5, 0.22, 0.18), g.bool(), seed),
        1 => gen::erdos_renyi(n, n, nnz, seed),
        2 => gen::powerlaw(n, nnz, 1.3 + g.f64_unit(), seed),
        _ => gen::banded_hub(n, 1 + g.usize_in(0, 4), 2 + g.usize_in(0, 4), 16, seed),
    }
}

/// Random contiguous 1D partition: balanced, nnz-balanced, or arbitrary
/// sorted boundaries (which may include zero-row ranks) — strictly more
/// general than anything a [`Partitioner`] emits.
fn random_partition(g: &mut Gen, a: &Csr, ranks: usize) -> RowPartition {
    match g.usize_in(0, 3) {
        0 => RowPartition::balanced(a.nrows, ranks),
        1 => RowPartition::nnz_balanced(a, ranks),
        _ => {
            let mut cuts: Vec<usize> =
                (1..ranks).map(|_| g.usize_in(0, a.nrows + 1)).collect();
            cuts.sort_unstable();
            let mut starts = Vec::with_capacity(ranks + 1);
            starts.push(0);
            starts.extend(cuts);
            starts.push(a.nrows);
            RowPartition::from_starts(starts)
        }
    }
}

#[test]
fn prop_cover_ordering_and_validate_on_nonuniform_partitions() {
    forall("nonuniform-plan", 25, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 9);
        let part = random_partition(g, &a, ranks);
        let blocks = split_1d(&a, &part);
        let koenig = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let greedy = comm::plan(&blocks, &part, Strategy::Joint(Solver::Greedy), None);
        let col = comm::plan(&blocks, &part, Strategy::Column, None);
        let row = comm::plan(&blocks, &part, Strategy::Row, None);
        let adaptive = comm::plan(&blocks, &part, Strategy::Adaptive, None);
        // Structural invariants hold for every strategy on any partition.
        for plan in [&koenig, &greedy, &col, &row, &adaptive] {
            assert_eq!(
                comm::validate::validate(plan, &blocks),
                Ok(()),
                "{:?} invalid on starts {:?}",
                plan.strategy,
                part.starts
            );
        }
        // Cover-solver volume ordering per pair: the optimal joint cover
        // never exceeds the greedy cover or either single-sided cover, and
        // greedy never exceeds selecting every nonempty row AND column.
        // (Greedy vs a *single* side is deliberately not asserted — greedy
        // set cover carries a log-factor worst case against it.)
        let n = 16;
        for p in 0..ranks {
            for q in 0..ranks {
                if p == q {
                    continue;
                }
                let k = koenig.volume(p, q, n);
                assert!(k <= greedy.volume(p, q, n), "({p},{q}) koenig > greedy");
                assert!(k <= col.volume(p, q, n), "({p},{q}) koenig > column");
                assert!(k <= row.volume(p, q, n), "({p},{q}) koenig > row");
                assert!(
                    greedy.volume(p, q, n) <= col.volume(p, q, n) + row.volume(p, q, n),
                    "({p},{q}) greedy exceeds rows+cols bound"
                );
            }
        }
        assert!(koenig.total_volume(n) <= greedy.total_volume(n));
        assert!(koenig.total_volume(n) <= col.total_volume(n).min(row.total_volume(n)));
    });
}

#[test]
fn prop_partitioner_invariants() {
    forall("partitioner-invariants", 12, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 7);
        let topo = Topology::tsubame4(ranks);
        for partitioner in Partitioner::ALL {
            let part = partitioner.partition(&a, ranks, &topo, 8);
            assert_eq!(part.nparts, ranks, "{}", partitioner.name());
            assert_eq!(part.starts[0], 0);
            assert_eq!(*part.starts.last().unwrap(), a.nrows);
            assert!(part.starts.windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(
                rank_nnz(&a, &part).iter().sum::<u64>(),
                a.nnz() as u64,
                "{} lost nonzeros",
                partitioner.name()
            );
            let blocks = split_1d(&a, &part);
            let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
            assert_eq!(
                comm::validate::validate(&plan, &blocks),
                Ok(()),
                "{} plan invalid",
                partitioner.name()
            );
        }
    });
}

#[test]
fn prop_executor_exact_on_nonuniform_partitions() {
    forall("exec-nonuniform", 10, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 9);
        let n_dense = 1 + g.usize_in(0, 8);
        let part = random_partition(g, &a, ranks);
        let blocks = split_1d(&a, &part);
        let strategy = match g.usize_in(0, 4) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            2 => Strategy::Adaptive,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let hier = g.bool();
        let sched = hier.then(|| hierarchy::build(&plan, &topo));
        let b = Dense::from_vec(a.nrows, n_dense, g.vec_f32(a.nrows * n_dense));
        let (got, _) = exec::run(
            &part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &b,
            &NativeKernel,
        );
        let want = a.spmm(&b);
        let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
        assert!(
            err < 1e-3,
            "rel err {err} (starts {:?} hier={hier})",
            part.starts
        );
    });
}

#[test]
fn prop_sddmm_bitwise_on_nonuniform_partitions() {
    // Kernel-generic engine property: through ANY contiguous partition
    // (including zero-row ranks) and any strategy/routing, distributed
    // SDDMM is bitwise the serial oracle — stronger than the SpMM
    // tolerance property above, because every edge value has exactly one
    // producer.
    forall("sddmm-nonuniform", 10, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 9);
        let n_dense = 1 + g.usize_in(0, 8);
        let part = random_partition(g, &a, ranks);
        let blocks = split_1d(&a, &part);
        let strategy = match g.usize_in(0, 4) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            2 => Strategy::Adaptive,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let hier = g.bool();
        let sched = hier.then(|| hierarchy::build(&plan, &topo));
        let x = Dense::from_vec(a.nrows, n_dense, g.vec_f32(a.nrows * n_dense));
        let y = Dense::from_vec(a.nrows, n_dense, g.vec_f32(a.nrows * n_dense));
        let (got, _) = exec::run_sddmm_with(
            &part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &x,
            &y,
            &NativeKernel,
            &shiro::exec::ExecOpts::default(),
        );
        assert_eq!(
            got,
            a.sddmm(&x, &y),
            "starts {:?} hier={hier} {strategy:?}",
            part.starts
        );
    });
}

#[test]
fn prop_shared_plan_session_b_side_and_amortization() {
    // The plan-sharing satellite: a session executing SpMM then SDDMM from
    // one frozen plan reports identical B-side measured volume, and the
    // second call of each kernel does zero planning work and zero fresh
    // allocations (Amortization extended to the new kernels).
    forall("kernel-plan-sharing", 8, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 7);
        let n_dense = 1 + g.usize_in(0, 8);
        let partitioner = Partitioner::ALL[g.usize_in(0, Partitioner::ALL.len())];
        let strategy = match g.usize_in(0, 2) {
            0 => Strategy::Column,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let hier = g.bool();
        let d = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(strategy)
            .hierarchical(hier)
            .partitioner(partitioner)
            .plan(&a);
        let mut s = d.into_session(shiro::exec::ExecOpts::default(), true);
        let x = Dense::from_vec(a.nrows, n_dense, g.vec_f32(a.nrows * n_dense));
        let y = Dense::from_vec(a.nrows, n_dense, g.vec_f32(a.nrows * n_dense));
        let (_, spmm_stats) = s
            .execute(&ExecRequest::spmm(&y).kernel(&NativeKernel))
            .expect("thread-backend SpMM")
            .into_dense();
        let (e1, sddmm_stats) = s
            .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
            .expect("thread-backend SDDMM")
            .into_sparse();
        assert_eq!(
            spmm_stats.measured_b_volume(),
            sddmm_stats.measured_b_volume(),
            "B-side volume differs across kernels ({strategy:?} hier={hier})"
        );
        assert_eq!(e1, a.sddmm(&x, &y));
        // Second calls of both kernels: zero plan, zero fresh allocations.
        let _ = s
            .execute(&ExecRequest::spmm(&y).kernel(&NativeKernel))
            .expect("thread-backend SpMM");
        let (e2, sddmm2_stats) = s
            .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
            .expect("thread-backend SDDMM")
            .into_sparse();
        assert_eq!(e1, e2, "session SDDMM unstable across calls");
        assert_eq!(
            sddmm_stats.measured_b_volume(),
            sddmm2_stats.measured_b_volume()
        );
        use shiro::exec::KernelOp;
        for op in [KernelOp::Spmm, KernelOp::Sddmm] {
            let am = s.amortization_for(op);
            assert_eq!(am.calls(), 2, "{op:?}");
            assert_eq!(am.alloc_events[1], 0, "{op:?}: second call allocated");
            assert_eq!(am.plan_secs[1], 0.0, "{op:?}: second call planned");
        }
    });
}

#[test]
fn prop_cover_always_valid_and_optimal_order() {
    forall("cover-valid", 60, |g| {
        let a = random_matrix(g);
        let k = cover::solve(&a, Solver::Koenig, &Weights::default());
        let d = cover::solve(&a, Solver::Dinic, &Weights::default());
        let gr = cover::solve(&a, Solver::Greedy, &Weights::default());
        assert!(k.is_valid_for(&a), "König invalid");
        assert!(d.is_valid_for(&a), "Dinic invalid");
        assert!(gr.is_valid_for(&a), "greedy invalid");
        // Optimality: both exact solvers agree; greedy never better.
        assert_eq!(k.cost, d.cost, "exact solvers disagree");
        assert!(gr.cost >= k.cost, "greedy beat optimal");
        // Dominance (Eq. 10 denominators).
        assert!(k.mu() <= a.nonempty_rows().len());
        assert!(k.mu() <= a.nonempty_cols().len());
        // König bound: cover size == max matching ≤ min(|R|,|C|).
        assert!(k.mu() <= a.nonempty_rows().len().min(a.nonempty_cols().len()));
    });
}

#[test]
fn prop_weighted_cover_never_exceeds_single_strategies() {
    forall("weighted-cover-bound", 40, |g| {
        let a = random_matrix(g);
        let rw = 1 + g.usize_in(0, 8) as u64;
        let cw = 1 + g.usize_in(0, 8) as u64;
        let w = Weights {
            row: Some(vec![rw; a.nrows]),
            col: Some(vec![cw; a.ncols]),
        };
        let sol = cover::solve(&a, Solver::Dinic, &w);
        assert!(sol.is_valid_for(&a));
        let col_cost = a.nonempty_cols().len() as u64 * cw;
        let row_cost = a.nonempty_rows().len() as u64 * rw;
        assert!(
            sol.cost <= col_cost.min(row_cost),
            "weighted cover {} worse than single-strategy {} / {}",
            sol.cost,
            row_cost,
            col_cost
        );
    });
}

#[test]
fn prop_plan_conserves_nnz_and_covers() {
    forall("plan-conserves", 30, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 9);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let strategy = match g.usize_in(0, 5) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            2 => Strategy::Joint(Solver::Koenig),
            3 => Strategy::Adaptive,
            _ => Strategy::Joint(Solver::Greedy),
        };
        let plan = comm::plan(&blocks, &part, strategy, None);
        let mut off_diag_nnz = 0;
        let mut plan_nnz = 0;
        for p in 0..ranks {
            for q in 0..ranks {
                if p == q {
                    continue;
                }
                off_diag_nnz += blocks[p].off_diag[q].nnz();
                let pair = &plan.pairs[p][q];
                plan_nnz += pair.a_row_part.nnz() + pair.a_col_part.nnz();
                // Every col-part nonzero's column must be in b_rows; every
                // row-part nonzero's row must be in c_rows.
                for r in 0..pair.a_col_part.nrows {
                    for &c in pair.a_col_part.row_indices(r) {
                        assert!(pair.b_rows.binary_search(&c).is_ok());
                    }
                }
                for &r in &pair.a_row_part.nonempty_rows() {
                    assert!(pair.c_rows.binary_search(&r).is_ok());
                }
            }
        }
        assert_eq!(off_diag_nnz, plan_nnz, "nonzeros lost in planning");
    });
}

#[test]
fn prop_hier_schedule_conserves_rows() {
    forall("hier-conserves", 25, |g| {
        let a = random_matrix(g);
        let ranks = 4 * g.usize_in(2, 5); // multiples of group size 4
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(ranks);
        let sched = hierarchy::build(&plan, &topo);

        // (1) Dedup can only reduce inter-group rows.
        let n_dense = 8;
        assert!(
            sched.inter_group_bytes(n_dense)
                <= hierarchy::flat_inter_group_bytes(&plan, &topo, n_dense)
        );
        // (2) Every planned inter-group pair transfer is represented:
        // b_rows of pair (p,q) across groups ⊆ the (q, group(p)) flow union.
        for p in 0..ranks {
            for q in 0..ranks {
                if p == q || topo.group_of(p) == topo.group_of(q) {
                    continue;
                }
                let pair = &plan.pairs[p][q];
                if !pair.b_rows.is_empty() {
                    let flow = sched
                        .b_flows
                        .iter()
                        .find(|f| f.src == q && f.dst_group == topo.group_of(p))
                        .expect("missing B flow");
                    for r in &pair.b_rows {
                        assert!(flow.rows.binary_search(r).is_ok());
                    }
                }
                if !pair.c_rows.is_empty() {
                    let flow = sched
                        .c_flows
                        .iter()
                        .find(|f| f.dst == p && f.src_group == topo.group_of(q))
                        .expect("missing C flow");
                    for r in &pair.c_rows {
                        assert!(flow.rows.binary_search(r).is_ok());
                    }
                }
            }
        }
    });
}

#[test]
fn prop_executor_exact_for_random_configs() {
    forall("exec-exact", 12, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 9);
        let n_dense = 1 + g.usize_in(0, 16);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let strategy = match g.usize_in(0, 4) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            2 => Strategy::Adaptive,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let hier = g.bool();
        let sched = hier.then(|| hierarchy::build(&plan, &topo));
        let b = Dense::from_vec(
            a.nrows,
            n_dense,
            g.vec_f32(a.nrows * n_dense),
        );
        let (got, _) = exec::run(
            &part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &b,
            &NativeKernel,
        );
        let want = a.spmm(&b);
        let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
        assert!(err < 1e-3, "rel err {err} (ranks={ranks} hier={hier})");
    });
}

#[test]
fn prop_plan_transpose_mirror_valid_and_bitwise() {
    // `transposed` must produce a *validated* plan whose executed
    // output is bit-identical to planning Aᵀ from scratch, across
    // strategies × partitioners × random sparsity patterns. Inputs are
    // integer-exact (shiro::bench::int_matrix's argument), so float
    // addition is associative and bitwise equality is meaningful even
    // though the mirrored and from-scratch plans split nonzeros
    // differently.
    forall("plan-transpose-mirror", 14, |g| {
        let n = 1 << g.usize_in(5, 9); // 32..256
        let a = shiro::bench::int_matrix(n, n * (3 + g.usize_in(0, 6)), g.rng().next_u64());
        let ranks = g.usize_in(2, 9);
        let n_dense = 1 + g.usize_in(0, 8);
        let strategy = match g.usize_in(0, 6) {
            0 => Strategy::Block,
            1 => Strategy::Column,
            2 => Strategy::Row,
            3 => Strategy::Adaptive,
            4 => Strategy::Joint(Solver::Greedy),
            _ => Strategy::Joint(Solver::Koenig),
        };
        let partitioner = Partitioner::ALL[g.usize_in(0, Partitioner::ALL.len())];
        let hier = g.bool();
        let topo = Topology::tsubame4(ranks);
        let params = shiro::plan::PlanParams::default();
        let spec = PlanSpec::new(topo)
            .strategy(strategy)
            .hierarchical(hier)
            .partitioner(partitioner)
            .params(params);
        let fwd = spec.plan(&a);
        let bwd = fwd.transposed();
        // Structurally valid against the transposed blocks, role-swapped,
        // and volume-preserving (the cover is reused, not re-solved).
        assert_eq!(
            comm::validate::validate(&bwd.plan, &bwd.blocks),
            Ok(()),
            "{strategy:?}/{} mirrored plan invalid",
            partitioner.name()
        );
        assert_eq!(fwd.plan.total_volume(n_dense), bwd.plan.total_volume(n_dense));
        for p in 0..ranks {
            for q in 0..ranks {
                // Sparsity-oblivious (full_block) pairs mirror to
                // full_block — whole-block column sends both ways, no
                // role exchange.
                if p != q && !fwd.plan.pairs[q][p].full_block {
                    assert_eq!(bwd.plan.pairs[p][q].c_rows, fwd.plan.pairs[q][p].b_rows);
                    assert_eq!(bwd.plan.pairs[p][q].b_rows, fwd.plan.pairs[q][p].c_rows);
                }
            }
        }
        // Executed output: mirrored plan == from-scratch plan of Aᵀ ==
        // serial oracle, bit for bit.
        let at = a.transpose();
        let scratch = spec.plan(&at);
        let b = Dense::from_fn(n, n_dense, |i, j| ((i * 7 + j * 5) % 9) as f32 - 4.0);
        let want = at.spmm(&b);
        let (got_mirror, _) = bwd
            .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
            .expect("thread-backend SpMM")
            .into_dense();
        let (got_scratch, _) = scratch
            .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
            .expect("thread-backend SpMM")
            .into_dense();
        assert_eq!(
            got_mirror.data, want.data,
            "{strategy:?}/{}/hier={hier}: mirrored bits",
            partitioner.name()
        );
        assert_eq!(
            got_scratch.data, want.data,
            "{strategy:?}/{}/hier={hier}: scratch bits",
            partitioner.name()
        );
    });
}

#[test]
fn prop_hier_mirror_matches_rebuild() {
    // hierarchy::mirror(build(P)) == build(Pᵀ) on random plans — the
    // backward schedule really is the forward schedule with the two
    // patterns exchanged, at O(schedule) cost.
    forall("hier-mirror", 20, |g| {
        let a = random_matrix(g);
        let ranks = 4 * g.usize_in(1, 5);
        let part = random_partition(g, &a, ranks);
        let blocks = split_1d(&a, &part);
        let strategy = match g.usize_in(0, 3) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let sched = hierarchy::build(&plan, &topo);
        assert_eq!(
            hierarchy::mirror(&sched),
            hierarchy::build(&plan.transpose(), &topo),
            "{strategy:?} ranks={ranks}"
        );
        assert_eq!(hierarchy::mirror(&hierarchy::mirror(&sched)), sched);
    });
}

#[test]
fn prop_volume_matrix_consistency() {
    forall("volume-consistency", 30, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 12);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let n1 = 1 + g.usize_in(0, 8);
        let n2 = n1 * 2;
        // Volume scales exactly linearly in N (Eqs. 1-3, 9).
        assert_eq!(plan.total_volume(n2), 2 * plan.total_volume(n1));
        let m = plan.volume_matrix(n1);
        assert_eq!(m.total(), plan.total_volume(n1));
    });
}

#[test]
fn prop_recovery_replan_is_valid_and_cost_bounded() {
    // The crash-recovery replan (DESIGN.md §12) is `recover_partition`
    // followed by the ordinary plan pipeline. Over random matrices,
    // partitions, and crash ranks: the recovered partition is a
    // neighbor-absorption of the original, the parent's assemble/split
    // state rebuild is lossless, the replanned comm plan validates, and
    // its modeled α-β volume stays within the CostRefined objective
    // evaluated at the recovered partition for n-1 ranks (objective =
    // modeled joint cost + nonnegative straggler term).
    forall("recovery-replan", 20, |g| {
        let a = random_matrix(g);
        let ranks = g.usize_in(2, 9);
        let part = random_partition(g, &a, ranks);
        let lost = g.usize_in(0, ranks);
        let rec = recover_partition(&part, lost);
        assert_eq!(rec.nparts, ranks - 1, "lost {lost} of starts {:?}", part.starts);
        assert_eq!(rec.n, part.n);
        assert_eq!(rec.starts[0], 0);
        assert_eq!(*rec.starts.last().unwrap(), a.nrows);
        assert!(
            rec.starts.iter().all(|s| part.starts.contains(s)),
            "recovery invented a boundary: {:?} from {:?} (lost {lost})",
            rec.starts,
            part.starts
        );
        // The parent rebuilds worker state by assembling blocks back into
        // the full matrix; split→assemble must be the identity.
        let blocks = split_1d(&a, &rec);
        assert_eq!(assemble_1d(&blocks, &rec), a, "split/assemble roundtrip lost nonzeros");
        let strategy = match g.usize_in(0, 3) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let plan = comm::plan(&blocks, &rec, strategy, None);
        assert_eq!(
            comm::validate::validate(&plan, &blocks),
            Ok(()),
            "{strategy:?} replan invalid on recovered starts {:?}",
            rec.starts
        );
        let topo = Topology::tsubame4(rec.nparts);
        let n_dense = 1 + g.usize_in(0, 8);
        let joint = comm::plan(&blocks, &rec, Strategy::Joint(Solver::Koenig), None);
        let bound = refine_objective(&a, &rec, &topo, n_dense);
        let cost = shiro::plan::modeled_cost(&joint, &topo, n_dense);
        assert!(
            cost <= bound,
            "recovered joint plan cost {cost} exceeds CostRefined objective {bound} \
             at starts {:?}",
            rec.starts
        );
    });
}

#[test]
fn prop_replicated_bitwise_and_volume_monotone() {
    // The 1.5D contract (DESIGN.md §13), over random integer-exact inputs
    // × partitioners × cover strategies: for every factor c dividing the
    // rank count the replicated engine's bits equal the serial oracle's
    // (integer inputs make f32 addition exact, so the canonical fold
    // order turns the comparison into a bitwise pin rather than a
    // tolerance) — and hence the flat c=1 engine's; the deal-out schedule
    // validates against the group plan; and the modeled inter-group
    // volume never increases with c, because the group partitions nest
    // (coarsened boundaries), so per-pair covers merge and dedup.
    forall("replicated-exec", 6, |g| {
        let n = 1 << g.usize_in(5, 8); // 32..128
        let a = shiro::bench::int_matrix(n, n * (3 + g.usize_in(0, 5)), g.rng().next_u64());
        let ranks = 4 * (1 + g.usize_in(0, 3)); // 4..12, every c below divides
        let n_dense = 1 + g.usize_in(0, 6);
        let strategy = match g.usize_in(0, 3) {
            0 => Strategy::Column,
            1 => Strategy::Row,
            _ => Strategy::Joint(Solver::Koenig),
        };
        let partitioner = Partitioner::ALL[g.usize_in(0, Partitioner::ALL.len())];
        let b = Dense::from_fn(n, n_dense, |i, j| ((i * 7 + j * 3) % 9) as f32 - 4.0);
        let want = a.spmm(&b);
        let mut last_vol = None;
        for c in [1usize, 2, 4] {
            let d = PlanSpec::new(Topology::tsubame4(ranks))
                .strategy(strategy)
                .partitioner(partitioner)
                .n_dense(n_dense)
                .replicate(Replicate::Factor(c))
                .plan(&a);
            assert_eq!(d.rep.is_some(), c > 1);
            if let Some(rep) = &d.rep {
                assert_eq!(d.part.nparts, ranks / c);
                assert_eq!(
                    rep.validate(&d.plan),
                    Ok(()),
                    "c={c} {strategy:?}/{}",
                    partitioner.name()
                );
            }
            let (got, _) = d
                .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            assert_eq!(
                got.data, want.data,
                "c={c} {strategy:?}/{} ranks={ranks}: bits differ from serial",
                partitioner.name()
            );
            let vol = d.plan.total_volume(n_dense);
            if let Some(prev) = last_vol {
                assert!(
                    vol <= prev,
                    "c={c} {strategy:?}/{}: inter-group volume grew {prev} -> {vol}",
                    partitioner.name()
                );
            }
            last_vol = Some(vol);
        }
        // `auto` must land on a divisor and still produce the same bits.
        let d = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(strategy)
            .partitioner(partitioner)
            .n_dense(n_dense)
            .replicate(Replicate::Auto)
            .plan(&a);
        if let Some(rep) = &d.rep {
            assert_eq!(ranks % rep.map.c, 0, "auto picked a non-divisor");
            assert_eq!(rep.validate(&d.plan), Ok(()));
        }
        let (got, _) = d
            .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
            .expect("thread-backend SpMM")
            .into_dense();
        assert_eq!(got.data, want.data, "auto: bits differ from serial");
    });
}

#[test]
fn prop_partition_owner_roundtrip() {
    forall("partition-roundtrip", 60, |g| {
        let n = 1 + g.usize_in(0, 5000);
        let parts = 1 + g.usize_in(0, 64);
        let part = RowPartition::balanced(n, parts);
        assert_eq!(part.starts[parts], n);
        // Spot-check random rows.
        for _ in 0..20 {
            if n == 0 {
                break;
            }
            let r = g.usize_in(0, n);
            let (p, local) = part.to_local(r);
            assert!(p < parts);
            assert_eq!(part.to_global(p, local), r);
            let (lo, hi) = part.range(p);
            assert!((lo..hi).contains(&r));
        }
    });
}
