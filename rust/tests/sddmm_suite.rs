//! Differential SDDMM suite: the kernel-generic engine pinned against the
//! serial [`Csr::sddmm`] oracle, **bitwise**, across the full
//! configuration matrix — strategies × partitioners × flat/hierarchical
//! routing × overlap on/off × 1/2/4/8 worker threads — mirroring
//! `integration_spmm`'s determinism matrix via the shared
//! `bench::int_matrix` oracle. SDDMM is actually stronger than SpMM here:
//! every edge value has exactly one producer and a fixed dot order, so
//! bitwise equality holds on *arbitrary float* inputs too (pinned below),
//! not just integer-exact ones. The fused SDDMM→SpMM kernel accumulates,
//! so its bitwise gate runs on integer-exact inputs like SpMM's.

use shiro::bench::int_matrix;
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::{KernelOp, NativeKernel};
use shiro::exec::ExecOpts;
use shiro::partition::Partitioner;
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::rng::Rng;

fn int_xy(n: usize, k: usize) -> (Dense, Dense) {
    // Distinct small-integer operands: products and partial sums stay well
    // inside f32's exact range, and X ≠ Y exercises the asymmetric case.
    let x = Dense::from_fn(n, k, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
    let y = Dense::from_fn(n, k, |i, j| ((i * 3 + j * 11) % 7) as f32 - 3.0);
    (x, y)
}

fn opts_matrix() -> Vec<ExecOpts> {
    let mut v = Vec::new();
    for overlap in [true, false] {
        for workers in [1usize, 2, 4, 8] {
            let base = if overlap { ExecOpts::default() } else { ExecOpts::sequential() };
            v.push(ExecOpts { workers, ..base });
        }
    }
    v
}

#[test]
fn sddmm_bitwise_full_configuration_matrix() {
    // The satellite matrix: strategies × partitioners × routing × overlap
    // × workers, every cell bitwise-equal to the serial oracle.
    let a = int_matrix(256, 2048, 42);
    let (x, y) = int_xy(256, 8);
    let want = a.sddmm(&x, &y);
    for strategy in [
        Strategy::Block,
        Strategy::Column,
        Strategy::Row,
        Strategy::Joint(Solver::Koenig),
        Strategy::Joint(Solver::Greedy),
        Strategy::Adaptive,
    ] {
        for partitioner in Partitioner::ALL {
            for hier in [false, true] {
                if hier && strategy == Strategy::Block {
                    continue; // block mode is defined flat-only in the paper
                }
                let d = PlanSpec::new(Topology::tsubame4(8))
                    .strategy(strategy)
                    .hierarchical(hier)
                    .partitioner(partitioner)
                    .plan(&a);
                for opts in opts_matrix() {
                    let (got, _) = d
                        .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel).opts(opts))
                        .expect("thread-backend SDDMM")
                        .into_sparse();
                    assert_eq!(
                        got,
                        want,
                        "{strategy:?}/{}/hier={hier}/{opts:?}: bits differ from oracle",
                        partitioner.name()
                    );
                }
            }
        }
    }
}

#[test]
fn sddmm_bitwise_even_on_arbitrary_floats() {
    // No integer-exactness crutch: single-producer entries + fixed dot
    // order make the oracle a bitwise oracle on any input.
    let a = gen::powerlaw(512, 6000, 1.4, 23);
    let mut rng = Rng::new(31);
    let x = Dense::random(512, 16, &mut rng);
    let y = Dense::random(512, 16, &mut rng);
    let want = a.sddmm(&x, &y);
    for hier in [false, true] {
        let d = PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .hierarchical(hier)
            .plan(&a);
        for opts in [ExecOpts::default(), ExecOpts::sequential()] {
            let (got, _) = d
                .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel).opts(opts))
                .expect("thread-backend SDDMM")
                .into_sparse();
            assert_eq!(got, want, "hier={hier}/{opts:?}");
        }
    }
}

#[test]
fn fused_bitwise_across_partitioners_overlap_workers() {
    let a = int_matrix(256, 2048, 77);
    let (x, y) = int_xy(256, 4);
    let want = a.sddmm(&x, &y).spmm(&y);
    for partitioner in Partitioner::ALL {
        for hier in [false, true] {
            let d = PlanSpec::new(Topology::tsubame4(8))
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(hier)
                .partitioner(partitioner)
                .plan(&a);
            for opts in opts_matrix() {
                let (got, _) = d
                    .execute(&ExecRequest::fused(&x, &y).kernel(&NativeKernel).opts(opts))
                    .expect("thread-backend fused kernel")
                    .into_dense();
                assert_eq!(
                    got.data,
                    want.data,
                    "{}/hier={hier}/{opts:?}: fused bits differ from oracle chain",
                    partitioner.name()
                );
            }
        }
    }
}

#[test]
fn sddmm_across_rank_counts_and_tile_heights() {
    let a = int_matrix(192, 1600, 9);
    let (x, y) = int_xy(192, 8);
    let want = a.sddmm(&x, &y);
    let want_fused = want.spmm(&y);
    for ranks in [1usize, 2, 3, 5, 8, 16] {
        let d = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(Strategy::Joint(Solver::Koenig))
            .hierarchical(ranks > 2)
            .plan(&a);
        for tile_rows in [0usize, 7] {
            let opts = ExecOpts { tile_rows, ..ExecOpts::default() };
            let (got, _) = d
                .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel).opts(opts))
                .expect("thread-backend SDDMM")
                .into_sparse();
            assert_eq!(got, want, "ranks={ranks} tile={tile_rows}");
        }
        let (c, _) = d
            .execute(&ExecRequest::fused(&x, &y).kernel(&NativeKernel))
            .expect("thread-backend fused kernel")
            .into_dense();
        assert_eq!(c.data, want_fused.data, "ranks={ranks} fused");
    }
}

#[test]
fn shared_plan_session_serves_all_three_kernels() {
    // One frozen plan, one session: SpMM, SDDMM, and fused interleaved.
    // B-side volume identical across kernels; each op steady from its
    // second call; results stable across calls.
    let a = int_matrix(256, 2400, 55);
    let (x, y) = int_xy(256, 8);
    let e_want = a.sddmm(&x, &y);
    let c_want = a.spmm(&y);
    let f_want = e_want.spmm(&y);
    for hier in [false, true] {
        let d = PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .hierarchical(hier)
            .plan(&a);
        let mut s = d.into_session(ExecOpts::default(), true);
        let mut b_volumes = Vec::new();
        for _ in 0..2 {
            let (c, spmm_stats) = s
                .execute(&ExecRequest::spmm(&y).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            assert_eq!(c.data, c_want.data, "hier={hier}");
            let (e, sddmm_stats) = s
                .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
                .expect("thread-backend SDDMM")
                .into_sparse();
            assert_eq!(e, e_want, "hier={hier}");
            let (f, _) = s
                .execute(&ExecRequest::fused(&x, &y).kernel(&NativeKernel))
                .expect("thread-backend fused kernel")
                .into_dense();
            assert_eq!(f.data, f_want.data, "hier={hier}");
            b_volumes.push((spmm_stats.measured_b_volume(), sddmm_stats.measured_b_volume()));
        }
        for (sp, sd) in &b_volumes {
            assert!(sp.total() > 0, "hier={hier}: degenerate B side");
            assert_eq!(sp, sd, "hier={hier}: B-side volume differs across kernels");
        }
        for op in [KernelOp::Spmm, KernelOp::Sddmm, KernelOp::FusedSddmmSpmm] {
            let am = s.amortization_for(op);
            assert_eq!(am.calls(), 2, "{op:?}");
            assert!(am.steady_state(), "{op:?} hier={hier}: not steady");
            assert_eq!(am.alloc_events[1], 0, "{op:?} hier={hier}: second call allocated");
            assert_eq!(am.plan_secs[1], 0.0, "{op:?} hier={hier}: second call planned");
        }
    }
}

#[test]
fn sddmm_respects_pattern_values_and_structure() {
    // The sampled product scales by A's stored values — including explicit
    // zeros, which must stay (structurally) and produce zero values.
    let mut coo = shiro::sparse::Coo::new(64, 64);
    for i in 0..64usize {
        coo.push(i, (i * 7) % 64, 2.0);
        coo.push(i, (i * 13) % 64, 0.0); // explicit structural zero
    }
    let a = coo.to_csr();
    let (x, y) = int_xy(64, 4);
    let want = a.sddmm(&x, &y);
    let d = PlanSpec::new(Topology::tsubame4(4))
        .strategy(Strategy::Joint(Solver::Koenig))
        .plan(&a);
    let (got, _) = d
        .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
        .expect("thread-backend SDDMM")
        .into_sparse();
    assert_eq!(got, want);
    assert_eq!(got.nnz(), a.nnz(), "structure must be preserved exactly");
}
