//! Serving-layer integration suite (DESIGN.md §11): the multi-tenant
//! [`Server`] pinned against direct `ExecRequest` execution. Everything
//! runs on integer-exact inputs so "same answer" means **bitwise equal**:
//! whatever the queue, the micro-batcher, or the session registry do to a
//! request, the tenant must get exactly the bits a standalone
//! `PlanSpec::plan(..).execute(..)` would have produced.

use std::thread;
use std::time::Duration;

use shiro::bench::int_matrix;
use shiro::dense::Dense;
use shiro::runtime::multiproc::ProcOpts;
use shiro::serve::{Server, ServeConfig, ServeError, ServeRequest};
use shiro::sparse::Csr;
use shiro::spmm::{Backend, DistSpmm, ExecRequest, PlanSpec};
use shiro::topology::Topology;

const N: usize = 96;

fn graphs(m: usize) -> Vec<Csr> {
    (0..m).map(|i| int_matrix(N, 900 + 40 * i, 11 + i as u64)).collect()
}

fn int_b(ncols: usize, seed: usize) -> Dense {
    Dense::from_fn(N, ncols, |i, j| ((i * (3 + seed) + j * 7 + seed) % 9) as f32 - 4.0)
}

fn cfg(nranks: usize) -> ServeConfig {
    let mut c = ServeConfig::new(Topology::tsubame4(nranks));
    c.workers = 0; // deterministic drain_* driving unless a test opts in
    c
}

fn direct(a: &Csr, nranks: usize) -> DistSpmm {
    PlanSpec::new(Topology::tsubame4(nranks)).plan(a)
}

#[test]
fn concurrent_clients_over_multiple_graphs_bitwise() {
    // 6 client threads × 3 tenant graphs, every response compared bitwise
    // against a standalone plan of the same graph. Worker threads, the
    // shared registry, and any coalescing that happens under contention
    // must all be invisible in the bits.
    let graphs = graphs(3);
    let mut c = cfg(4);
    c.workers = 2;
    c.registry_cap = 3;
    let mut srv = Server::new(c);
    for (i, a) in graphs.iter().enumerate() {
        srv.register_graph(&format!("g{i}"), a.clone());
    }
    let plans: Vec<DistSpmm> = graphs.iter().map(|a| direct(a, 4)).collect();
    thread::scope(|s| {
        for client in 0..6usize {
            let srv = &srv;
            let plans = &plans;
            s.spawn(move || {
                for round in 0..4usize {
                    let gi = (client + round) % plans.len();
                    let b = int_b(3 + (client + round) % 4, client * 10 + round);
                    let got = srv
                        .submit_wait(ServeRequest::spmm(&format!("g{gi}"), b.clone()))
                        .unwrap_or_else(|e| panic!("client {client} round {round}: {e}"))
                        .into_dense();
                    let (want, _) = plans[gi]
                        .execute(&ExecRequest::spmm(&b))
                        .expect("thread-backend SpMM")
                        .into_dense();
                    assert_eq!(
                        got.data, want.data,
                        "client {client} round {round} graph g{gi}: bits differ from direct"
                    );
                }
            });
        }
    });
    let stats = srv.shutdown();
    assert_eq!(stats.completed, 24);
    assert_eq!(stats.failed, 0);
    // 3 graphs under a capacity-3 registry: one build per graph, no
    // evictions, every later lookup a hit. (Lookups are per *execute*, so
    // opportunistic coalescing under contention only lowers their total.)
    assert_eq!(stats.registry_misses, 3);
    assert_eq!(stats.registry_evictions, 0);
    assert_eq!(stats.latency().count, 24, "one latency sample per request");
}

#[test]
fn microbatch_is_bitwise_identical_across_mixed_widths() {
    // Five same-graph SpMM requests with five different B widths coalesce
    // into one execute; each split response must match its own standalone
    // execute bit for bit, and the batch counters must account for all of
    // them.
    let a = int_matrix(N, 1100, 5);
    let mut c = cfg(4);
    c.max_batch = 8;
    let srv = Server::new(c);
    srv.register_graph("g", a.clone());
    let d = direct(&a, 4);
    let bs: Vec<Dense> = (0..5).map(|i| int_b(1 + i, 40 + i)).collect();
    let tickets: Vec<_> = bs
        .iter()
        .map(|b| srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap())
        .collect();
    assert_eq!(srv.drain_all(), 1, "five coalescable requests must run as one execute");
    for (i, (t, b)) in tickets.into_iter().zip(&bs).enumerate() {
        let resp = t.wait().unwrap();
        assert_eq!(resp.batch_size, 5, "request {i} rode the wrong batch");
        let (want, _) =
            d.execute(&ExecRequest::spmm(b)).expect("thread-backend SpMM").into_dense();
        let got = resp.into_dense();
        assert_eq!(got.ncols, b.ncols);
        assert_eq!(got.data, want.data, "request {i}: batched bits differ from unbatched");
    }
    let stats = srv.stats();
    assert_eq!(stats.completed, 5);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.batched_requests, 5);
    assert_eq!(stats.max_batch_seen, 5);
    assert_eq!(stats.mean_batch(), 5.0);
}

#[test]
fn batch_cap_and_cross_graph_isolation() {
    // max_batch = 2 splits four same-graph requests into two executes, and
    // a different tenant's request never rides another graph's batch.
    let a = int_matrix(N, 900, 6);
    let h = int_matrix(N, 950, 7);
    let mut c = cfg(2);
    c.max_batch = 2;
    let srv = Server::new(c);
    srv.register_graph("g", a.clone());
    srv.register_graph("h", h.clone());
    let b = int_b(4, 3);
    let tg: Vec<_> = (0..4)
        .map(|_| srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap())
        .collect();
    let th = srv.try_submit(ServeRequest::spmm("h", b.clone())).unwrap();
    // Executes: {g,g}, {g,g}, {h} — the h request keeps its queue slot but
    // never coalesces across graphs.
    assert_eq!(srv.drain_all(), 3);
    for t in tg {
        assert_eq!(t.wait().unwrap().batch_size, 2);
    }
    let rh = th.wait().unwrap();
    assert_eq!(rh.batch_size, 1);
    let (want_h, _) =
        direct(&h, 2).execute(&ExecRequest::spmm(&b)).expect("thread-backend SpMM").into_dense();
    assert_eq!(rh.into_dense().data, want_h.data, "cross-graph isolation broke the bits");
}

#[test]
fn registry_capacity_evicts_lru_and_rebuilds() {
    // Capacity 2, three graphs, then a revisit: g0 must be evicted by g2
    // and rebuilt on return — and the rebuilt session still serves the
    // same bits.
    let graphs = graphs(3);
    let mut c = cfg(2);
    c.registry_cap = 2;
    let srv = Server::new(c);
    for (i, a) in graphs.iter().enumerate() {
        srv.register_graph(&format!("g{i}"), a.clone());
    }
    let b = int_b(3, 9);
    let mut serve = |gi: usize| {
        let t = srv.try_submit(ServeRequest::spmm(&format!("g{gi}"), b.clone())).unwrap();
        srv.drain_all();
        t.wait().unwrap().into_dense()
    };
    let first = serve(0); // miss: build g0
    serve(1); // miss: build g1
    serve(2); // miss: build g2, evict g0 (LRU)
    serve(1); // hit: g1 stayed warm
    let again = serve(0); // miss: g0 rebuilt, evicting g2
    let s = srv.stats();
    assert_eq!(s.registry_misses, 4, "expected g0,g1,g2,g0-again to miss");
    assert_eq!(s.registry_hits, 1, "expected only the g1 revisit to hit");
    assert_eq!(s.registry_evictions, 2, "expected g0 then g2 evicted at capacity");
    assert_eq!(first.data, again.data, "rebuilt session served different bits");
}

#[test]
fn admission_rejections_are_eager_and_structured() {
    let a = int_matrix(N, 800, 8);
    let mut c = cfg(2);
    c.queue_cap = 3;
    let mut srv = Server::new(c);
    srv.register_graph("g", a);
    let b = int_b(2, 1);

    match srv.try_submit(ServeRequest::spmm("ghost", b.clone())) {
        Err(ServeError::UnknownGraph(name)) => assert_eq!(name, "ghost"),
        other => panic!("expected UnknownGraph, got {other:?}"),
    }

    let queued: Vec<_> = (0..3)
        .map(|_| srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap())
        .collect();
    match srv.try_submit(ServeRequest::spmm("g", b.clone())) {
        Err(ServeError::Saturated { cap }) => assert_eq!(cap, 3),
        other => panic!("expected Saturated at queue_cap, got {other:?}"),
    }
    assert_eq!(srv.queue_len(), 3, "rejected request must not occupy a slot");

    // Shutdown fulfills every queued ticket with a structured error —
    // no client is left blocked on wait().
    let stats = srv.shutdown();
    for t in queued {
        match t.wait() {
            Err(ServeError::Shutdown) => {}
            other => panic!("expected Shutdown for drained ticket, got {other:?}"),
        }
    }
    // 1 unknown graph + 1 saturated + 3 drained at shutdown.
    assert_eq!(stats.rejected, 5);
    assert_eq!(stats.completed, 0);
    match srv.try_submit(ServeRequest::spmm("g", b)) {
        Err(ServeError::Shutdown) => {}
        other => panic!("expected Shutdown after shutdown, got {other:?}"),
    }
}

#[test]
fn proc_backend_requests_match_thread_through_the_server() {
    // A tenant may ask for the multiprocess backend; the server routes it
    // through the session's frozen plan and the bits must match the
    // thread-backend response for the same graph and operand.
    let a = int_matrix(N, 1000, 13);
    let srv = Server::new(cfg(2));
    srv.register_graph("g", a);
    let b = int_b(4, 17);
    let popts = ProcOpts {
        timeout: Duration::from_secs(60),
        worker_exe: Some(env!("CARGO_BIN_EXE_shiro").into()),
        fault: None,
        pool: None,
    };
    let tt = srv.try_submit(ServeRequest::spmm("g", b.clone())).unwrap();
    let tp = srv
        .try_submit(ServeRequest::spmm("g", b).backend(Backend::Proc(popts)))
        .unwrap();
    // Thread + proc on the same graph: one session, two executes (the proc
    // request is not coalescable).
    assert_eq!(srv.drain_all(), 2);
    let c_thread = tt.wait().unwrap().into_dense();
    let c_proc = tp.wait().unwrap().into_dense();
    assert_eq!(c_thread.data, c_proc.data, "proc-backend bits differ through the server");
    let s = srv.stats();
    assert_eq!(s.completed, 2);
    // Sessions are keyed by backend too — thread and proc requests on the
    // same graph build separate registry entries.
    assert_eq!((s.registry_hits, s.registry_misses), (0, 2));
}

#[test]
fn server_pools_proc_workers_across_requests() {
    // Proc requests that arrive without a pool get the server's shared
    // per-(topology, nranks) pool injected: the fleet spawns once, every
    // later request reuses the live connections, and the aggregate
    // counters surface in ServeStats — while staying bitwise against the
    // thread backend.
    let a = int_matrix(N, 1000, 13);
    let mut srv = Server::new(cfg(2));
    srv.register_graph("g", a.clone());
    let d = direct(&a, 2);
    let popts = || ProcOpts {
        timeout: Duration::from_secs(60),
        worker_exe: Some(env!("CARGO_BIN_EXE_shiro").into()),
        fault: None,
        pool: None, // the server injects its shared pool
    };
    for round in 0..3usize {
        let b = int_b(3, round);
        let t = srv
            .try_submit(ServeRequest::spmm("g", b.clone()).backend(Backend::Proc(popts())))
            .unwrap();
        srv.drain_all();
        let got = t.wait().unwrap().into_dense();
        let (want, _) =
            d.execute(&ExecRequest::spmm(&b)).expect("thread-backend SpMM").into_dense();
        assert_eq!(got.data, want.data, "round {round}: pooled proc bits differ");
    }
    let s = srv.stats();
    assert_eq!(s.pool_spawns, 2, "one spawn per rank across all requests");
    assert_eq!(s.pool_reuses, 2, "rounds after the first reuse the warm fleet");
    assert_eq!(s.pool_readmissions, 0);
    let s = srv.shutdown();
    assert_eq!(s.pool_spawns, 2, "pool counters stay readable at shutdown");
}
