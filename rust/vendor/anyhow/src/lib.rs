//! Offline drop-in subset of the `anyhow` error crate.
//!
//! The build image has no crates.io access (DESIGN.md §1), so this vendored
//! path dependency provides exactly the surface the workspace uses:
//!
//! - [`Error`] / [`Result`] with `{}` (outermost message) and `{:#}`
//!   (full cause chain) formatting,
//! - a blanket `From<E: std::error::Error>` so `?` converts library errors,
//! - the [`Context`] trait on `Result` and `Option`,
//! - the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl legal.

use std::fmt;

/// Error type: an outermost message plus the rendered cause chain.
pub struct Error {
    head: String,
    /// Causes, outermost first (each entry one `source()` deeper).
    chain: Vec<String>,
}

impl Error {
    /// Construct from a plain message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { head: message.to_string(), chain: Vec::new() }
    }

    /// Wrap with an outer context message (what `Context` uses).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.head);
        chain.extend(self.chain);
        Error { head: context.to_string(), chain }
    }

    /// The rendered cause chain, outermost cause first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.head)?;
        if f.alternate() {
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.head)?;
        for cause in &self.chain {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { head: e.to_string(), chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error arm of a `Result` or to `None`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(5).is_err());
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
